//! FLUX fine-grained fused overlap — the paper's contribution (§3, §4),
//! as a tile-level schedule on the cluster simulator.
//!
//! GEMM+ReduceScatter (Alg. 1): ONE kernel per rank; every output tile's
//! epilogue P2P-stores straight to its destination rank. Tile-coordinate
//! swizzling (§4.1) staggers which destination each rank hits at any
//! instant. Communication rides the tail of tiles as they finish — the
//! Fig. 5 "T_f" timeline.
//!
//! AllGather+GEMM (Alg. 2/3): the host transfer loop moves communication
//! tiles (pull- or push-based, ring order after the local rank) and sets
//! signals; the single fused kernel's tiles spin on the signal guarding
//! their A rows, local tiles first — the Fig. 6 timeline.

use crate::cost::arch::{ClusterSpec, Intra};
use crate::cost::gemm::{tile_grid, TileTask};
use crate::overlap::tiles::{
    comm_schedule, swizzle_order, swizzle_order_local_first, CommTile,
};
use crate::overlap::{Op, OpTiming, Problem, BF16};
use crate::sim::cluster::Cluster;
use crate::sim::device::GatedTile;
use crate::sim::resources::Time;

/// How the ReduceScatter's reduction half executes (§4.2 "Reduce").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceStrategy {
    /// red/atomic instructions straight into destination memory. Free in
    /// time but unavailable for bf16 on A100/H800 (§4.2 footnote 5):
    /// stores then go out in f32, doubling epilogue bytes.
    RedAtomic,
    /// Hopper warp/thread-block specialization: a consumer warp on the
    /// destination pulls ready remote partials and reduces locally —
    /// bf16-safe, costs a small per-store consumer latency.
    WarpSpecialized,
    /// Discrete reduction kernel after the AlltoAll (the decoupled
    /// baseline; always what inter-node traffic uses).
    Discrete,
}

/// Tuning knobs (§4.4). `comm_rows = 0` means "medium chunk size"
/// (m / N_TP), the starting point of the Fig.-10 sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FluxConfig {
    /// Tile-coordinate swizzling (§4.1).
    pub swizzle: bool,
    /// Pull-based (vs push-based) AllGather transfers (§4.3, Fig. 9).
    pub pull: bool,
    /// AllGather communication-tile rows (§4.3, Fig. 10). 0 = chunk size.
    pub comm_rows: usize,
    /// Fuse the local reduction into the kernel (Alg. 1 Reduce branch)
    /// instead of a discrete reduction kernel.
    pub fuse_reduction: bool,
    /// Which fused-reduction implementation (§4.2); only meaningful when
    /// `fuse_reduction` is set.
    pub reduce: ReduceStrategy,
}

impl Default for FluxConfig {
    fn default() -> Self {
        FluxConfig {
            swizzle: true,
            pull: true,
            comm_rows: 0,
            fuse_reduction: true,
            reduce: ReduceStrategy::WarpSpecialized,
        }
    }
}

impl FluxConfig {
    /// The configuration auto-tuning converges to per interconnect
    /// (tuner::tune searches the full space; this is the known best
    /// starting point): pull on NVLink, push on PCIe (Fig. 9).
    pub fn for_cluster(spec: &ClusterSpec) -> FluxConfig {
        FluxConfig {
            pull: matches!(spec.intra, Intra::NvLink { .. }),
            // §4.2: warp/thread-block specialization is the Hopper
            // path. On Ampere bf16 atomics are unsupported (footnote 5)
            // and f32 atomics double the wire bytes, so the tuned A100
            // choice is the decoupled Write branch + discrete local
            // reduce ("fusing AlltoAll is typically enough, the
            // reduction fusion only provides marginal gain", §3.1).
            fuse_reduction: spec.arch.name == "H800",
            reduce: if spec.arch.name == "H800" {
                ReduceStrategy::WarpSpecialized
            } else {
                ReduceStrategy::Discrete
            },
            ..Default::default()
        }
    }
}

/// Jitter sigma matching medium.rs — same production environment; Flux's
/// robustness comes from launching ONE kernel, not from calmer streams.
use crate::overlap::medium::PROD_JITTER_SIGMA;

pub fn simulate(
    cluster: &ClusterSpec,
    p: &Problem,
    cfg: &FluxConfig,
    seed: u64,
) -> OpTiming {
    let mut c =
        Cluster::new(cluster, p.n_tp, seed).with_jitter(PROD_JITTER_SIGMA);
    let overall = match p.op {
        Op::GemmRs => simulate_rs(&mut c, p, cfg),
        Op::AgGemm => simulate_ag(&mut c, p, cfg),
    };
    OpTiming {
        overall_ns: overall,
        gemm_nonsplit_ns: p.gemm_nonsplit_ns(cluster),
    }
}

/// Row-tile traversal order for one rank.
fn traversal(tiles_m: usize, rank: usize, n_tp: usize, cfg: &FluxConfig,
             local_first: bool) -> Vec<usize> {
    if cfg.swizzle && tiles_m % n_tp == 0 {
        if local_first {
            swizzle_order_local_first(tiles_m, rank, n_tp)
        } else {
            swizzle_order(tiles_m, rank, n_tp)
        }
    } else {
        (0..tiles_m).collect()
    }
}

// ---------------------------------------------------------------------------
// GEMM + ReduceScatter
// ---------------------------------------------------------------------------

struct PendingStore {
    ready: Time,
    src: usize,
    dst: usize,
    bytes: f64,
}

fn simulate_rs(c: &mut Cluster, p: &Problem, cfg: &FluxConfig) -> f64 {
    let n = p.n_tp;
    let shape = p.local_gemm();
    let arch = c.spec.arch;
    let (tile, tasks) = tile_grid(&arch, &shape);
    let tiles_m = shape.m.div_ceil(tile.bm);
    let tn = shape.n.div_ceil(tile.bn);
    let rows_per_rank = p.m / n;

    // §6 H800 cliff: per-destination store slivers narrower than the
    // minimum efficient TMA store slow the epilogue down.
    let narrow = rows_per_rank.min(tile.bm) < arch.min_store_rows;
    let store_penalty =
        if narrow { 1.0 / arch.narrow_store_penalty } else { 1.0 };

    // §4.2 reduce-strategy costs (only with fused reduction):
    //  - RedAtomic: bf16 atomics unsupported (footnote 5) => partials
    //    travel as f32: store bytes double.
    //  - WarpSpecialized: bf16 on the wire, small consumer handoff
    //    latency folded into the store completion.
    let (store_byte_factor, store_extra_ns) = if cfg.fuse_reduction {
        match cfg.reduce {
            ReduceStrategy::RedAtomic => (2.0, 0.0),
            ReduceStrategy::WarpSpecialized => (1.0, 600.0),
            ReduceStrategy::Discrete => (1.0, 0.0),
        }
    } else {
        (1.0, 0.0)
    };

    // Index tasks by (ti, tj) for traversal reordering.
    let task_at = |ti: usize, tj: usize| -> &TileTask {
        &tasks[ti * tn + tj]
    };

    // Pre-size: one store per (tile, covered dest); local stores are
    // free (p2p_store no-op) and skipped outright (§Perf L3-3).
    let mut stores: Vec<PendingStore> =
        Vec::with_capacity(tasks.len() * n);
    let mut kernel_end = vec![0.0f64; n];
    for r in 0..n {
        let order = traversal(tiles_m, r, n, cfg, false);
        // Single fused kernel launch.
        let ov = c.devices[r].launch_overhead();
        let t0 = ov;
        let mut end: f64 = t0;
        for &ti in &order {
            for tj in 0..tn {
                let t = task_at(ti, tj);
                let dur = t.dur_ns * store_penalty;
                let (_, e) = c.devices[r].sm.acquire(t0, dur);
                end = end.max(e);
                // Epilogue store(s): the tile's rows may span several
                // destination ranks when rows_per_rank < bm.
                let row0 = ti * tile.bm;
                let row1 = row0 + t.rows;
                let mut d0 = row0 / rows_per_rank;
                while d0 * rows_per_rank < row1 {
                    let lo = row0.max(d0 * rows_per_rank);
                    let hi = row1.min((d0 + 1) * rows_per_rank);
                    if d0 != r {
                        stores.push(PendingStore {
                            ready: e,
                            src: r,
                            dst: d0,
                            bytes: (hi - lo) as f64 * t.cols as f64
                                * BF16 * store_byte_factor,
                        });
                    }
                    d0 += 1;
                }
            }
        }
        kernel_end[r] = end;
    }

    // Feed all epilogue stores through the interconnect in ready order:
    // ingress FIFO per destination models the §4.1 memory-controller
    // contention the swizzle avoids.
    stores.sort_unstable_by(|a, b| a.ready.total_cmp(&b.ready));
    let mut last_arrival = vec![0.0f64; n];
    for s in &stores {
        let (_, e) = c.net.p2p_store(s.src, s.dst, s.bytes, s.ready);
        last_arrival[s.dst] = last_arrival[s.dst].max(e + store_extra_ns);
    }

    // Reduction: fused (red/atomic or specialized-warp, §4.2) costs
    // nothing extra; discrete reduction adds a memory-bound kernel.
    // Multi-node always reduces the inter-node part discretely (§4.2).
    let nodes = n.div_ceil(c.spec.gpus_per_node);
    let discrete = !cfg.fuse_reduction
        || cfg.reduce == ReduceStrategy::Discrete
        || nodes > 1;
    let reduce_ns = if discrete {
        // Read the n received partial slices + write the reduced one.
        let slice = (p.m / n) as f64 * p.n as f64 * BF16;
        let bytes = (n + 1) as f64 * slice;
        arch.launch_us * 1e3 + bytes / arch.hbm_gbps
    } else {
        0.0
    };

    (0..n)
        .map(|r| kernel_end[r].max(last_arrival[r] + reduce_ns))
        .fold(0.0, f64::max)
}

// ---------------------------------------------------------------------------
// AllGather + GEMM
// ---------------------------------------------------------------------------

fn simulate_ag(c: &mut Cluster, p: &Problem, cfg: &FluxConfig) -> f64 {
    let n = p.n_tp;
    let shape = p.local_gemm();
    let arch = c.spec.arch;
    let (tile, tasks) = tile_grid(&arch, &shape);
    let tiles_m = shape.m.div_ceil(tile.bm);
    let tn = shape.n.div_ceil(tile.bn);
    let rows_per_rank = p.m / n;

    // Communication tile rows: default = medium chunk size; must divide
    // the per-rank shard.
    let mut comm_rows = if cfg.comm_rows == 0 {
        rows_per_rank
    } else {
        cfg.comm_rows.min(rows_per_rank)
    };
    while rows_per_rank % comm_rows != 0 {
        comm_rows -= 1;
    }

    // Pull/push asymmetry (§4.3 Fig. 9): PCIe reads (pull) pay the
    // request round-trip (≈25% effective bandwidth loss); NVLink pushes
    // pay a remote-signal write + ordering flush per tile (a small
    // bandwidth tax plus extra signal latency).
    let (byte_factor, extra_sig_ns) = match (c.spec.intra, cfg.pull) {
        (Intra::Pcie { .. }, true) => (1.0 / 0.75, 0.0),
        (Intra::Pcie { .. }, false) => (1.0, 0.0),
        (Intra::NvLink { .. }, true) => (1.0, 0.0),
        (Intra::NvLink { .. }, false) => (1.08, 2.0e3),
    };
    let sig_lat = c.spec.signal_latency_us * 1e3 + extra_sig_ns;
    let bytes_per_row = p.k as f64 * BF16;

    // row_sig[rank][row-tile] = when that row-tile's signal is visible.
    // Local rows' signals are preset (stay at 0).
    let mut row_sig = vec![vec![0.0f64; tiles_m]; n];
    let record = |row_sig: &mut Vec<Vec<f64>>, rank: usize,
                      row0: usize, rows: usize, sig: f64| {
        let t0 = row0 / tile.bm;
        let t1 = (row0 + rows - 1) / tile.bm;
        for ti in t0..=t1.min(tiles_m - 1) {
            row_sig[rank][ti] = row_sig[rank][ti].max(sig);
        }
    };

    let single_node = n <= c.spec.gpus_per_node;
    let nvlink = matches!(c.spec.intra, Intra::NvLink { .. });
    if single_node && nvlink {
        // §4.3 NVLink: direct communication, ring order after the local
        // rank, one sequential host chain per rank (the Alg. 3 loop).
        struct Chain {
            items: Vec<CommTile>,
            next: usize,
            ready: Time,
        }
        let mut chains: Vec<Chain> = (0..n)
            .map(|r| Chain {
                items: comm_schedule(p.m, r, n, comm_rows, cfg.pull),
                next: 0,
                ready: 0.0,
            })
            .collect();
        // K-way merge: advance the chain whose next transfer is ready
        // earliest so link FIFO order matches simulated time order.
        loop {
            let Some(ci) = earliest_ready(
                chains
                    .iter()
                    .enumerate()
                    .filter(|(_, ch)| ch.next < ch.items.len())
                    .map(|(i, ch)| (i, ch.ready)),
            ) else {
                break;
            };
            let (t, ready) = {
                let ch = &chains[ci];
                (ch.items[ch.next], ch.ready)
            };
            let bytes = t.rows as f64 * bytes_per_row * byte_factor;
            let (_, end) = c.net.transfer(t.src, t.dst, bytes, ready);
            chains[ci].ready = end;
            chains[ci].next += 1;
            record(&mut row_sig, t.dst, t.row0, t.rows, end + sig_lat);
        }
    } else {
        // §4.3 PCIe (and any multi-node config): ring-relay
        // communication. Each communication tile hops neighbor-to-
        // neighbor; cross-node ring edges ride the NICs. This moves
        // every byte over the shared PCIe uplinks / NICs exactly once —
        // the bandwidth-efficient schedule the paper describes (the
        // NUMA/NIC-aware issue order falls out of the ring direction).
        // Finer comm tiles pipeline the ring (visible in Fig. 10).
        let rows_per_rank = p.m / n;
        let tiles_per_shard = rows_per_rank / comm_rows;
        // have[r][global_comm_tile] = when rank r holds that tile.
        let total_tiles = n * tiles_per_shard;
        let mut have = vec![vec![f64::INFINITY; total_tiles]; n];
        for r in 0..n {
            for t in 0..tiles_per_shard {
                have[r][r * tiles_per_shard + t] = 0.0;
            }
        }
        let mut chain_ready = vec![0.0f64; n];
        // Relay direction chosen so shard (r+1) arrives first, (r+2)
        // second, ... — aligned with the kernel's local-first ring
        // traversal (§4.1: swizzle must match signal arrival order).
        for hop in 1..n {
            for tt in 0..tiles_per_shard {
                for r in 0..n {
                    let src = (r + 1) % n;
                    let shard = (r + hop) % n;
                    let gt = shard * tiles_per_shard + tt;
                    let ready = chain_ready[r].max(have[src][gt]);
                    debug_assert!(ready.is_finite(),
                        "relay dependency not yet satisfied");
                    let bytes =
                        comm_rows as f64 * bytes_per_row * byte_factor;
                    let (_, end) = c.net.transfer(src, r, bytes, ready);
                    have[r][gt] = end;
                    chain_ready[r] = end;
                    record(
                        &mut row_sig,
                        r,
                        shard * rows_per_rank + tt * comm_rows,
                        comm_rows,
                        end + sig_lat,
                    );
                }
            }
        }
    }

    // Fused kernels: tiles spin on their row signal (Alg. 2), traversed
    // local-rank-first then ring order (§4.1 applied to AG).
    let mut overall: f64 = 0.0;
    for r in 0..n {
        let order = traversal(tiles_m, r, n, cfg, true);
        let mut gated = Vec::with_capacity(tasks.len());
        for &ti in &order {
            for tj in 0..tn {
                let t = &tasks[ti * tn + tj];
                gated.push(GatedTile {
                    signal: row_sig[r][ti],
                    dur: t.dur_ns,
                });
            }
        }
        let kt = c.devices[r].launch_signal_gated(0.0, &gated);
        overall = overall.max(kt.end);
    }
    overall
}

/// Index of the earliest-ready chain among `(index, ready)` pairs.
/// `total_cmp` keeps the k-way merge total even for a non-finite
/// `ready` (NaN sorts after every real time) — the old
/// `partial_cmp().unwrap()` panicked there (flux-lint rule D002). For
/// the finite times the transfer model produces, the order (and every
/// pinned report byte) is identical.
fn earliest_ready(
    ready: impl Iterator<Item = (usize, f64)>,
) -> Option<usize> {
    ready.min_by(|a, b| a.1.total_cmp(&b.1)).map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, A100_PCIE, H800_NVLINK};
    use crate::overlap::{baseline, medium};

    fn ag(m: usize) -> Problem {
        Problem::ag(m, 49152, 12288, 8)
    }
    fn rs(m: usize) -> Problem {
        Problem::rs(m, 12288, 49152, 8)
    }
    fn flux(cluster: &crate::cost::arch::ClusterSpec, p: &Problem)
        -> OpTiming
    {
        simulate(cluster, p, &FluxConfig::for_cluster(cluster), 1)
    }

    #[test]
    fn earliest_ready_is_nan_safe() {
        // Regression: a non-finite `ready` used to panic the NVLink
        // k-way merge via `partial_cmp().unwrap()`. Under `total_cmp`
        // NaN orders after every finite time, so the merge keeps
        // draining the well-formed chains deterministically.
        let nan = f64::NAN;
        assert_eq!(
            earliest_ready([(0, nan), (1, 1.0)].into_iter()),
            Some(1)
        );
        assert_eq!(
            earliest_ready([(0, 2.0), (1, nan), (2, 0.5)].into_iter()),
            Some(2)
        );
        // All-NaN still selects something instead of panicking.
        assert_eq!(
            earliest_ready([(0, nan), (1, nan)].into_iter()),
            Some(0)
        );
        assert_eq!(earliest_ready(std::iter::empty()), None);
        // Finite ties keep `min_by`'s first-minimum choice — the same
        // chain the pre-fix code advanced, so pinned report bytes are
        // unchanged.
        assert_eq!(
            earliest_ready([(0, 3.0), (1, 3.0)].into_iter()),
            Some(0)
        );
    }

    #[test]
    fn flux_beats_te_across_the_sweep() {
        // Fig. 11-13 headline: Flux >= TE on every evaluated shape.
        for m in [1024usize, 2048, 4096, 8192] {
            for p in [ag(m), rs(m)] {
                for cl in [&A100_PCIE, &A100_NVLINK, &H800_NVLINK] {
                    let f = flux(cl, &p);
                    let te = medium::simulate(cl, &p, 1);
                    assert!(
                        f.overall_ns < te.overall_ns,
                        "{} m={m} on {}: flux {} te {}",
                        p.op.name(), cl.name, f.overall_ns, te.overall_ns
                    );
                }
            }
        }
    }

    #[test]
    fn flux_beats_baseline_at_scale() {
        for m in [2048usize, 8192] {
            for p in [ag(m), rs(m)] {
                for cl in [&A100_PCIE, &A100_NVLINK, &H800_NVLINK] {
                    let f = flux(cl, &p);
                    let b = baseline::simulate(cl, &p);
                    assert!(
                        f.overall_ns < b.overall_ns,
                        "{} m={m} on {}: flux {} base {}",
                        p.op.name(), cl.name, f.overall_ns, b.overall_ns
                    );
                }
            }
        }
    }

    #[test]
    fn overlap_efficiency_is_high_on_nvlink_large_m() {
        // §5.1: up to 96% on A100 NVLink.
        let p = rs(8192);
        let f = flux(&A100_NVLINK, &p);
        let b = baseline::simulate(&A100_NVLINK, &p);
        let eff = f.overlap_efficiency(&b);
        assert!(eff > 0.35 && eff <= 1.0, "eff {eff}");
    }

    #[test]
    fn swizzle_helps_rs() {
        // Fig. 8: contention of the naive mapping.
        let p = rs(8192);
        let on = simulate(&A100_NVLINK, &p,
                          &FluxConfig { swizzle: true, ..Default::default() }, 1);
        let off = simulate(&A100_NVLINK, &p,
                           &FluxConfig { swizzle: false, ..Default::default() }, 1);
        assert!(on.overall_ns < off.overall_ns,
                "on {} off {}", on.overall_ns, off.overall_ns);
    }

    #[test]
    fn swizzle_helps_ag() {
        let p = ag(8192);
        let cfg_on = FluxConfig { comm_rows: 128, ..Default::default() };
        let cfg_off = FluxConfig { swizzle: false, comm_rows: 128,
                                   ..Default::default() };
        let on = simulate(&A100_NVLINK, &p, &cfg_on, 1);
        let off = simulate(&A100_NVLINK, &p, &cfg_off, 1);
        assert!(on.overall_ns < off.overall_ns,
                "on {} off {}", on.overall_ns, off.overall_ns);
    }

    #[test]
    fn pull_push_preference_depends_on_interconnect() {
        // Fig. 9: PCIe and NVLink prefer different transfer directions.
        let p = ag(4096);
        let pull = FluxConfig { pull: true, comm_rows: 256, ..Default::default() };
        let push = FluxConfig { pull: false, comm_rows: 256, ..Default::default() };
        let d_pcie = simulate(&A100_PCIE, &p, &pull, 1).overall_ns
            - simulate(&A100_PCIE, &p, &push, 1).overall_ns;
        assert!(d_pcie > 0.0, "PCIe should prefer push ({d_pcie})");
        // On NVLink pull is never worse (push pays the remote-signal
        // tax); at compute-bound shapes the difference may be ~0.
        let d_nvl = simulate(&A100_NVLINK, &p, &pull, 1).overall_ns
            - simulate(&A100_NVLINK, &p, &push, 1).overall_ns;
        assert!(d_nvl <= 0.0, "NVLink should prefer pull ({d_nvl})");
    }

    #[test]
    fn comm_tile_size_matters() {
        // Fig. 10: different sizes give different times; no universal
        // winner is asserted, only that the knob is live. PCIe's ring
        // relay makes the pipelining effect visible.
        let p = ag(8192);
        let times: Vec<f64> = [1024usize, 512, 256, 128]
            .iter()
            .map(|&rows| {
                simulate(&A100_PCIE, &p,
                    &FluxConfig { comm_rows: rows, ..Default::default() }, 1)
                    .overall_ns
            })
            .collect();
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = times.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 1.005, "knob appears dead: {times:?}");
    }

    #[test]
    fn h800_small_m_narrow_store_cliff() {
        // §6: m=64 RS on H800 with 8-way TP stores 8-row slivers — the
        // one case the paper reports Flux losing to TE.
        let p = rs(64);
        let f = flux(&H800_NVLINK, &p);
        let b = baseline::simulate(&H800_NVLINK, &p);
        // Flux may lose to the non-overlapping baseline here (negative
        // efficiency, matching Fig. 14's H800 row).
        let eff = f.overlap_efficiency(&b);
        assert!(eff < 0.5, "eff should collapse at m=64 on H800: {eff}");
    }

    #[test]
    fn multinode_16way_works() {
        // Fig. 15: 16-way TP over 2 nodes.
        let p = Problem::ag(8192, 49152, 12288, 16);
        for cl in [&A100_PCIE, &A100_NVLINK, &H800_NVLINK] {
            let f = flux(cl, &p);
            let b = baseline::simulate(cl, &p);
            assert!(f.overall_ns > 0.0);
            assert!(
                f.overall_ns < 2.0 * b.overall_ns,
                "multinode flux sane on {}", cl.name
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let p = ag(2048);
        let a = flux(&A100_NVLINK, &p).overall_ns;
        let b = flux(&A100_NVLINK, &p).overall_ns;
        assert_eq!(a, b);
    }
}
