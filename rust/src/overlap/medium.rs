//! Medium-grained overlap: the prior technique (TransformerEngine
//! UserBuffer, Wang et al., Jangda et al.) — §2.2 and the "TE" bars of
//! every evaluation figure.
//!
//! The original GEMM is split into N_TP chunk kernels; chunk P2P
//! transfers ride a ring and overlap with other chunks' compute. The
//! §2.2 limitations are modeled explicitly:
//!
//! 1. every chunk is a *separate kernel*: per-launch overhead plus
//!    stream-timing jitter, and no wave sharing across kernels;
//! 2. in ReduceScatter the partial-sum adds create data dependences that
//!    serialize the chunk GEMMs (no multiplexing);
//! 3. each chunk GEMM has 1/N the rows: wave quantization and small-m
//!    inefficiency multiply (the dominant loss at small m).

use crate::cost::arch::ClusterSpec;
use crate::cost::gemm::{tile_grid, GemmShape};
use crate::overlap::{Op, OpTiming, Problem, BF16};
use crate::sim::cluster::Cluster;

/// Stream-jitter sigma used for multi-kernel methods when simulating the
/// production environment the paper describes (§2.2). Deterministic per
/// seed.
pub const PROD_JITTER_SIGMA: f64 = 0.25;

pub fn simulate(cluster: &ClusterSpec, p: &Problem, seed: u64) -> OpTiming {
    let mut c = Cluster::new(cluster, p.n_tp, seed)
        .with_jitter(PROD_JITTER_SIGMA);
    let overall = match p.op {
        Op::AgGemm => simulate_ag(&mut c, p),
        Op::GemmRs => simulate_rs(&mut c, p),
    };
    OpTiming { overall_ns: overall, gemm_nonsplit_ns: p.gemm_nonsplit_ns(cluster) }
}

/// AllGather overlap: ring-exchange the x chunks; each arrived chunk
/// unblocks an independent chunk GEMM (these can multiplex on the SM
/// pool — AG's advantage over RS in Fig. 4).
fn simulate_ag(c: &mut Cluster, p: &Problem) -> f64 {
    let n = p.n_tp;
    let chunk_rows = p.m / n;
    let chunk_bytes = chunk_rows as f64 * p.k as f64 * BF16;
    let chunk_shape =
        GemmShape::new(chunk_rows, p.n / n, p.k);
    let (_, tiles) = tile_grid(&c.spec.arch, &chunk_shape);

    // Ring steps: at step s, rank r receives chunk (r-s mod n) from
    // rank r-1. All ranks do this simultaneously; per-rank arrival time
    // chains through its ingress.
    let mut overall: f64 = 0.0;
    for r in 0..n {
        // Arrival time of each chunk at rank r.
        let mut arrival = vec![0.0f64; n];
        let mut prev_end = 0.0f64;
        for s in 1..n {
            let src = (r + n - 1) % n; // ring neighbor
            let chunk = (r + n - s) % n;
            let (_, end) = c.net.transfer(src, r, chunk_bytes, prev_end);
            arrival[chunk] = end;
            prev_end = end;
        }
        // Chunk GEMMs are separate kernels. Unlike the single fused
        // FLUX kernel they do NOT share waves: each occupies the device
        // (its own launch, its own partial last wave). Streams let a
        // chunk's launch overlap the previous kernel's drain, but a
        // GEMM-sized kernel at full occupancy leaves no room for true
        // co-residency — the §2.2/§3.3 split-GEMM efficiency loss.
        // Local chunk first, then arrival (ring) order.
        let mut end_r: f64 = 0.0;
        for s in 0..n {
            let chunk = (r + n - s) % n;
            let issue = end_r.max(arrival[chunk]);
            let t = c.devices[r].launch_uniform(
                issue,
                tiles.len(),
                tiles[0].dur_ns,
            );
            end_r = t.end;
        }
        overall = overall.max(end_r);
    }
    overall
}

/// ReduceScatter overlap: chunk GEMMs are *serialized* by the partial-sum
/// dependence chain (§2.2 limitation 2); each finished chunk's partial is
/// sent to its destination and added there.
fn simulate_rs(c: &mut Cluster, p: &Problem) -> f64 {
    let n = p.n_tp;
    let chunk_rows = p.m / n;
    let chunk_bytes = chunk_rows as f64 * p.n as f64 * BF16;
    let chunk_shape = GemmShape::new(chunk_rows, p.n, p.k / n);
    let (_, tiles) = tile_grid(&c.spec.arch, &chunk_shape);

    // Add kernel: 2 reads + 1 write of the chunk, memory bound.
    let add_bytes = 3.0 * chunk_bytes;
    let add_ns = c.spec.arch.launch_us * 1e3
        + add_bytes / c.spec.arch.hbm_gbps;

    let mut overall: f64 = 0.0;
    for r in 0..n {
        // Serialized chunk GEMMs (dependence chain through the adds).
        let mut gemm_end = 0.0f64;
        let mut pipe_end = 0.0f64; // transfer+add pipeline tail
        for s in 0..n {
            // Chunk for destination rank (r + 1 + s) % n, farthest first.
            let dest = (r + 1 + s) % n;
            let t = c.devices[r].launch_uniform(
                gemm_end,
                tiles.len(),
                tiles[0].dur_ns,
            );
            gemm_end = t.end;
            if dest != r {
                let (_, arr) =
                    c.net.transfer(r, dest, chunk_bytes, gemm_end);
                // The destination's add kernel (we charge it to the
                // pipeline tail; adds on different ranks overlap).
                pipe_end = pipe_end.max(arr) + add_ns;
            }
        }
        overall = overall.max(gemm_end.max(pipe_end));
    }
    overall
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::arch::{A100_NVLINK, H800_NVLINK};
    use crate::overlap::baseline;

    /// GPT-3 shapes from §5.1.
    fn ag(m: usize) -> Problem {
        Problem::ag(m, 49152, 12288, 8)
    }
    fn rs(m: usize) -> Problem {
        Problem::rs(m, 12288, 49152, 8)
    }

    #[test]
    fn te_beats_baseline_at_large_m_ag() {
        // Fig. 4: AG at large m is where TE helps.
        let p = ag(8192);
        let te = simulate(&H800_NVLINK, &p, 1);
        let base = baseline::simulate(&H800_NVLINK, &p);
        assert!(
            te.overall_ns < base.overall_ns,
            "te {} base {}",
            te.overall_ns,
            base.overall_ns
        );
    }

    #[test]
    fn te_loses_to_baseline_at_small_m() {
        // Fig. 4 / Fig. 14: splitting a small GEMM is catastrophic.
        let p = ag(64);
        let te = simulate(&A100_NVLINK, &p, 1);
        let base = baseline::simulate(&A100_NVLINK, &p);
        assert!(
            te.overall_ns > base.overall_ns,
            "te {} base {}",
            te.overall_ns,
            base.overall_ns
        );
    }

    #[test]
    fn rs_overlaps_worse_than_ag() {
        // Fig. 4: the add-dependence chain hurts RS more than AG.
        let pa = ag(4096);
        let pr = rs(4096);
        let te_ag = simulate(&H800_NVLINK, &pa, 1);
        let te_rs = simulate(&H800_NVLINK, &pr, 1);
        let b_ag = baseline::simulate(&H800_NVLINK, &pa);
        let b_rs = baseline::simulate(&H800_NVLINK, &pr);
        let eff_ag = te_ag.overlap_efficiency(&b_ag);
        let eff_rs = te_rs.overlap_efficiency(&b_rs);
        assert!(eff_ag > eff_rs, "AG eff {eff_ag} vs RS eff {eff_rs}");
    }

    #[test]
    fn split_gemm_cost_exceeds_nonsplit() {
        // Even with perfect comm overlap the chunked GEMMs cost more than
        // the monolithic GEMM (Fig. 5's T_m > T_g).
        let p = ag(1024);
        let te = simulate(&A100_NVLINK, &p, 3);
        assert!(te.overall_ns > te.gemm_nonsplit_ns);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = rs(2048);
        let a = simulate(&A100_NVLINK, &p, 9).overall_ns;
        let b = simulate(&A100_NVLINK, &p, 9).overall_ns;
        assert_eq!(a, b);
        let c = simulate(&A100_NVLINK, &p, 10).overall_ns;
        assert_ne!(a, c, "jitter should differ across seeds");
    }
}
