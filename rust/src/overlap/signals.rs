//! Signal protocol (§4.3 "Signals"): 32-bit flags set by the host
//! transfer loop (cuStreamWriteValue) and spun on by kernel tiles.
//!
//! The numeric twin executes sequentially, so `wait` must *observe* a
//! set signal — a wait on an unset signal is the deadlock the real
//! kernel would hit. This module enforces the protocol's safety
//! invariants (preset locals, set-before-wait, no double-set, reset
//! between uses) and records the observed ordering for tests.

use anyhow::{bail, Result};

#[derive(Clone, Debug)]
pub struct SignalSet {
    /// Logical step at which each signal was set (None = unset).
    set_at: Vec<Option<u64>>,
    /// Number of waits observed per signal.
    waits: Vec<u64>,
    step: u64,
}

impl SignalSet {
    /// All signals allocated contiguously and unset (the paper allocates
    /// them contiguously for easy preset/reset).
    pub fn new(n: usize) -> SignalSet {
        SignalSet { set_at: vec![None; n], waits: vec![0; n], step: 0 }
    }

    pub fn len(&self) -> usize {
        self.set_at.len()
    }

    pub fn is_empty(&self) -> bool {
        self.set_at.is_empty()
    }

    fn tick(&mut self) -> u64 {
        self.step += 1;
        self.step
    }

    /// Preset (local tiles' signals are always true, §3.2).
    pub fn preset(&mut self, i: usize) {
        let s = self.tick();
        self.set_at[i] = Some(s);
    }

    /// Host-side SetSignal after a DataTransfer completes.
    pub fn set(&mut self, i: usize) -> Result<()> {
        if self.set_at[i].is_some() {
            bail!("signal {i} set twice without reset");
        }
        let s = self.tick();
        self.set_at[i] = Some(s);
        Ok(())
    }

    /// Kernel-side WaitSignal: in the sequential twin the signal must
    /// already be set, otherwise the fused kernel would deadlock.
    pub fn wait(&mut self, i: usize) -> Result<()> {
        match self.set_at[i] {
            Some(_) => {
                self.waits[i] += 1;
                Ok(())
            }
            None => bail!(
                "deadlock: tile waited on signal {i} before its transfer \
                 was issued"
            ),
        }
    }

    /// Reset after the GEMM completes (§4.3: reset with a stream+event to
    /// avoid racing the next iteration). Fails if any signal was never
    /// consumed *and* never set — that would mean the schedule under-
    /// covered the input.
    pub fn reset(&mut self) -> Result<()> {
        for (i, s) in self.set_at.iter().enumerate() {
            if s.is_none() {
                bail!("signal {i} never set before reset");
            }
        }
        self.set_at.iter_mut().for_each(|s| *s = None);
        self.waits.iter_mut().for_each(|w| *w = 0);
        Ok(())
    }

    pub fn wait_count(&self, i: usize) -> u64 {
        self.waits[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn happy_path() {
        let mut s = SignalSet::new(4);
        s.preset(0);
        s.set(1).unwrap();
        s.set(2).unwrap();
        s.set(3).unwrap();
        for i in 0..4 {
            s.wait(i).unwrap();
        }
        s.reset().unwrap();
        // Reusable after reset.
        s.set(1).unwrap();
    }

    #[test]
    fn wait_before_set_is_deadlock() {
        let mut s = SignalSet::new(2);
        assert!(s.wait(1).is_err());
    }

    #[test]
    fn double_set_rejected() {
        let mut s = SignalSet::new(1);
        s.set(0).unwrap();
        assert!(s.set(0).is_err());
    }

    #[test]
    fn reset_requires_full_coverage() {
        let mut s = SignalSet::new(2);
        s.set(0).unwrap();
        assert!(s.reset().is_err(), "signal 1 never set");
    }

    #[test]
    fn wait_counts() {
        let mut s = SignalSet::new(1);
        s.preset(0);
        s.wait(0).unwrap();
        s.wait(0).unwrap();
        assert_eq!(s.wait_count(0), 2);
    }
}
