//! Tile bookkeeping: swizzling, destination routing, communication
//! schedules. Rust twin of `python/compile/kernels` (ref.swizzle_order,
//! ref.ring_comm_order, ref.tile_dest, flux_ag_gemm.comm_tile_schedule);
//! cross-checked against `artifacts/golden_swizzle.json` in
//! rust/tests/golden.rs.

/// FLUX tile-coordinate swizzling (§4.1): rank r starts its traversal at
/// peer (r+1)'s block, so at any instant the N ranks write to N distinct
/// destination devices (Fig. 7).
pub fn swizzle_order(num_tiles: usize, rank: usize, n_tp: usize) -> Vec<usize> {
    assert!(num_tiles % n_tp == 0, "tiles {num_tiles} % n_tp {n_tp} != 0");
    let per = num_tiles / n_tp;
    let start = ((rank + 1) % n_tp) * per;
    (0..num_tiles).map(|i| (start + i) % num_tiles).collect()
}

/// AG-side traversal: local rank's tiles first (their signals are
/// preset), then peers in ring-arrival order. Twin of
/// `_swizzle_m_local_first` in flux_ag_gemm.py.
pub fn swizzle_order_local_first(
    num_tiles: usize,
    rank: usize,
    n_tp: usize,
) -> Vec<usize> {
    assert!(num_tiles % n_tp == 0);
    let per = num_tiles / n_tp;
    let start = rank * per;
    (0..num_tiles).map(|i| (start + i) % num_tiles).collect()
}

/// Host-side communication order on NVLink (§4.3): ring starting after
/// the local rank; e.g. rank 5 of 8 → [6, 7, 0, 1, 2, 3, 4].
pub fn ring_comm_order(rank: usize, n_tp: usize) -> Vec<usize> {
    (0..n_tp - 1).map(|i| (rank + 1 + i) % n_tp).collect()
}

/// Destination rank of an output row-tile in GEMM+ReduceScatter.
///
/// Hard-asserts the divisibility precondition: with `tiles_m % n_tp !=
/// 0` the integer division silently routes boundary tiles to the wrong
/// rank, and release builds (the tier-1 path) used to sail right past
/// the old `debug_assert!`.
pub fn tile_dest(tile_m: usize, tiles_m: usize, n_tp: usize) -> usize {
    assert!(
        tiles_m % n_tp == 0,
        "tile_dest: tiles_m {tiles_m} not divisible by n_tp {n_tp}"
    );
    assert!(tile_m < tiles_m, "tile_dest: tile {tile_m} >= grid {tiles_m}");
    tile_m / (tiles_m / n_tp)
}

/// One host-side tile transfer of the AllGather (Alg. 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CommTile {
    pub src: usize,
    pub dst: usize,
    /// First row of the aggregated A buffer this tile covers.
    pub row0: usize,
    pub rows: usize,
    /// Signal index guarding this tile (peer-major, tile-minor).
    pub signal: usize,
}

/// The host transfer schedule for one rank's AllGather (Alg. 3), ring
/// order after the local rank, `rows` rows per communication tile.
/// Twin of flux_ag_gemm.comm_tile_schedule (pull orientation; the push
/// variant swaps src/dst at the caller).
pub fn comm_schedule(
    m: usize,
    rank: usize,
    n_tp: usize,
    rows: usize,
    pull: bool,
) -> Vec<CommTile> {
    assert!(m % n_tp == 0, "m {m} % n_tp {n_tp} != 0");
    let rows_per_rank = m / n_tp;
    assert!(
        rows_per_rank % rows == 0,
        "rows/rank {rows_per_rank} not divisible by comm tile {rows}"
    );
    let tiles_per_rank = rows_per_rank / rows;
    let mut out = Vec::with_capacity((n_tp - 1) * tiles_per_rank);
    for peer in ring_comm_order(rank, n_tp) {
        for t in 0..tiles_per_rank {
            out.push(CommTile {
                src: if pull { peer } else { rank },
                dst: if pull { rank } else { peer },
                row0: peer * rows_per_rank + t * rows,
                rows,
                signal: peer * tiles_per_rank + t,
            });
        }
    }
    out
}

/// Candidate communication-tile row counts for auto-tuning (§4.3
/// Fig. 10): start at the medium-grained chunk size (m / N_TP) and halve
/// down to the GEMM tile's bm.
pub fn comm_tile_candidates(m: usize, n_tp: usize, bm: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut rows = m / n_tp;
    while rows >= bm && rows >= 1 {
        out.push(rows);
        if rows % 2 != 0 {
            break;
        }
        rows /= 2;
    }
    if out.is_empty() {
        out.push(m / n_tp);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn swizzle_is_permutation() {
        forall(64, 0xA11CE, |rng| {
            let n_tp = [2usize, 4, 8][rng.below(3) as usize];
            let per = rng.range(1, 9) as usize;
            let rank = rng.below(n_tp as u64) as usize;
            let order = swizzle_order(n_tp * per, rank, n_tp);
            let mut s = order.clone();
            s.sort_unstable();
            assert_eq!(s, (0..n_tp * per).collect::<Vec<_>>());
        });
    }

    #[test]
    fn swizzle_ranks_never_collide() {
        // The Fig.-7 invariant: at each step, the N ranks' current tiles
        // map to N distinct destination ranks.
        forall(64, 0xBEE, |rng| {
            let n_tp = [2usize, 4, 8][rng.below(3) as usize];
            let per = rng.range(1, 9) as usize;
            let num = n_tp * per;
            let orders: Vec<Vec<usize>> =
                (0..n_tp).map(|r| swizzle_order(num, r, n_tp)).collect();
            for step in 0..num {
                let mut dests: Vec<usize> = (0..n_tp)
                    .map(|r| tile_dest(orders[r][step], num, n_tp))
                    .collect();
                dests.sort_unstable();
                dests.dedup();
                assert_eq!(dests.len(), n_tp, "collision at step {step}");
            }
        });
    }

    #[test]
    fn local_first_starts_at_own_block() {
        let order = swizzle_order_local_first(16, 2, 4);
        assert_eq!(order[0], 8); // rank 2's first tile (per = 4)
        assert_eq!(tile_dest(order[0], 16, 4), 2);
    }

    #[test]
    fn ring_order_paper_example() {
        assert_eq!(ring_comm_order(5, 8), vec![6, 7, 0, 1, 2, 3, 4]);
        assert_eq!(ring_comm_order(0, 2), vec![1]);
    }

    #[test]
    fn comm_schedule_covers_remote_rows_exactly() {
        forall(64, 0xC0FFEE, |rng| {
            let n_tp = [2usize, 4, 8][rng.below(3) as usize];
            let tiles_per_rank = [1usize, 2, 4][rng.below(3) as usize];
            let rows = 16usize;
            let rank = rng.below(n_tp as u64) as usize;
            let m = n_tp * tiles_per_rank * rows;
            let pull = rng.below(2) == 0;
            let sched = comm_schedule(m, rank, n_tp, rows, pull);
            let mut covered = vec![false; m];
            for t in &sched {
                let peer = if pull { t.src } else { t.dst };
                assert_ne!(peer, rank, "no transfer of local rows");
                for r in t.row0..t.row0 + t.rows {
                    assert_eq!(r / (m / n_tp), peer);
                    assert!(!covered[r], "row {r} transferred twice");
                    covered[r] = true;
                }
            }
            let rpr = m / n_tp;
            for (r, c) in covered.iter().enumerate() {
                let local = r / rpr == rank;
                assert_eq!(*c, !local, "row {r} coverage");
            }
        });
    }

    #[test]
    fn comm_schedule_signals_unique() {
        let sched = comm_schedule(256, 3, 8, 16, true);
        let mut sigs: Vec<usize> = sched.iter().map(|t| t.signal).collect();
        sigs.sort_unstable();
        sigs.dedup();
        assert_eq!(sigs.len(), sched.len());
    }

    #[test]
    fn comm_tile_candidates_halve_down_to_bm() {
        // m=8192, N=8: chunk 1024 → 512 → 256 → 128 (bm).
        assert_eq!(
            comm_tile_candidates(8192, 8, 128),
            vec![1024, 512, 256, 128]
        );
        // Tiny m: single candidate.
        assert_eq!(comm_tile_candidates(64, 8, 8), vec![8]);
    }
}
