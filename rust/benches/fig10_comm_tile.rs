//! Bench for Fig. 10: communication tile size sweep.
use flux::cost::arch::A100_NVLINK;
use flux::figures;
use flux::overlap::flux::{simulate, FluxConfig};
use flux::util::bench::Bench;

fn main() {
    figures::print_table(&figures::fig10());
    let mut b = Bench::new();
    let p = figures::ag_problem(8192, 8);
    for rows in [1024usize, 128] {
        let cfg = FluxConfig { comm_rows: rows, ..Default::default() };
        b.run(&format!("flux AG m=8192 comm_rows={rows}"), || {
            simulate(&A100_NVLINK, &p, &cfg, 7)
        });
    }
}
