//! Bench for Fig. 15: 16-way TP over two nodes.
use flux::cost::arch::H800_NVLINK;
use flux::figures;
use flux::overlap::flux::{simulate, FluxConfig};
use flux::overlap::Problem;
use flux::util::bench::Bench;

fn main() {
    figures::print_table(&figures::fig15());
    let mut b = Bench::new();
    let p = Problem::ag(8192, 49152, 12288, 16);
    b.run("flux AG m=8192 16-way (2 nodes)", || {
        simulate(&H800_NVLINK, &p, &FluxConfig::for_cluster(&H800_NVLINK), 7)
    });
}
