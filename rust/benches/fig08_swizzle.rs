//! Bench for Fig. 8: tile-coordinate swizzling on/off (8xA100 NVLink).
use flux::cost::arch::A100_NVLINK;
use flux::figures;
use flux::overlap::flux::{simulate, FluxConfig};
use flux::util::bench::Bench;

fn main() {
    figures::print_table(&figures::fig08());
    let mut b = Bench::new();
    let p = figures::rs_problem(8192, 8);
    for (name, sw) in [("swizzled", true), ("naive", false)] {
        let cfg = FluxConfig { swizzle: sw, ..Default::default() };
        b.run(&format!("flux RS m=8192 {name}"), || {
            simulate(&A100_NVLINK, &p, &cfg, 7)
        });
    }
}
