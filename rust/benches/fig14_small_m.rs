//! Bench for Fig. 14: small-m (decoding) shapes on all clusters.
use flux::cost::arch::H800_NVLINK;
use flux::figures;
use flux::overlap::flux::{simulate, FluxConfig};
use flux::util::bench::Bench;

fn main() {
    figures::print_table(&figures::fig14());
    let mut b = Bench::new();
    let p = figures::rs_problem(64, 8);
    b.run("flux RS m=64 H800 (narrow-store cliff)", || {
        simulate(&H800_NVLINK, &p, &FluxConfig::default(), 7)
    });
}
