//! Bench for Fig. 9: pull- vs push-based AllGather transfers.
use flux::cost::arch::A100_PCIE;
use flux::figures;
use flux::overlap::flux::{simulate, FluxConfig};
use flux::util::bench::Bench;

fn main() {
    figures::print_table(&figures::fig09());
    let mut b = Bench::new();
    let p = figures::ag_problem(4096, 8);
    for (name, pull) in [("pull", true), ("push", false)] {
        let cfg = FluxConfig { pull, comm_rows: 256, ..Default::default() };
        b.run(&format!("flux AG m=4096 PCIe {name}"), || {
            simulate(&A100_PCIE, &p, &cfg, 7)
        });
    }
}
