//! Bench for Figs. 11-13: the op-level three-way comparison on all
//! three clusters (ECT + overlap efficiency per Eq. 1/2).
use flux::cost::arch::{A100_NVLINK, A100_PCIE, H800_NVLINK};
use flux::figures;
use flux::util::bench::Bench;

fn main() {
    for cl in [&A100_PCIE, &A100_NVLINK, &H800_NVLINK] {
        println!("\n### {} ###", cl.name);
        figures::print_table(&figures::fig11_13(cl));
    }
    let mut b = Bench::new();
    let p = figures::rs_problem(4096, 8);
    b.run("tuner::tune RS m=4096 (full search)", || {
        flux::tuner::tune(&A100_NVLINK, &p, 7)
    });
}
