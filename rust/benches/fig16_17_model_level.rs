//! Bench for Figs. 1, 16 and 17: communication portions and model-level
//! training / prefill / decoding comparisons.
use flux::cost::arch::A100_PCIE;
use flux::figures;
use flux::model::configs::GPT3_175B;
use flux::parallel::{train_step_ns, Layout, Method};
use flux::util::bench::Bench;

fn main() {
    figures::print_table(&figures::fig01());
    figures::print_table(&figures::fig16());
    figures::print_table(&figures::fig17());
    let mut b = Bench::new();
    b.run("train_step_ns GPT-3 175B Flux 128xA100-PCIe", || {
        train_step_ns(&A100_PCIE, &GPT3_175B, &Layout::PAPER_TRAINING,
                      16, 2048, 2048, Method::Flux, 7)
    });
}
