//! L3 hot-path microbenchmarks (the §Perf targets in EXPERIMENTS.md):
//! the DES primitives, the tile scheduler, the fused-kernel simulators,
//! and the serving scheduler — the code the coordinator runs per op /
//! per request.
use flux::cost::arch::{A100_NVLINK, A100_PCIE};
use flux::figures;
use flux::overlap::flux::{simulate, FluxConfig};
use flux::overlap::tiles;
use flux::serving::kvcache::KvCacheManager;
use flux::serving::{Batcher, BatcherConfig, Request};
use flux::sim::cluster::Cluster;
use flux::sim::resources::Pool;
use flux::util::bench::Bench;

fn main() {
    let mut b = Bench::new();

    b.run("pool 6144-tile wave schedule", || {
        let mut p = Pool::new(216);
        let mut end = 0.0f64;
        for _ in 0..6144 {
            end = end.max(p.acquire(0.0, 100.0).1);
        }
        end
    });

    b.run("swizzle_order 64 tiles", || {
        tiles::swizzle_order(64, 3, 8)
    });

    b.run("comm_schedule m=8192 rows=128", || {
        tiles::comm_schedule(8192, 3, 8, 128, true)
    });

    let p_rs = figures::rs_problem(8192, 8);
    b.run("flux RS sim m=8192 NVLink (end-to-end op)", || {
        simulate(&A100_NVLINK, &p_rs, &FluxConfig::default(), 7)
    });
    let p_ag = figures::ag_problem(8192, 8);
    b.run("flux AG sim m=8192 PCIe ring-relay", || {
        simulate(&A100_PCIE, &p_ag,
                 &FluxConfig::for_cluster(&A100_PCIE), 7)
    });

    b.run("cluster construction (8 ranks)", || {
        Cluster::new(&A100_NVLINK, 8, 7)
    });

    b.run("batcher admit+decode 64 requests", || {
        let mut batcher = Batcher::new(BatcherConfig {
            max_prefill_batch: 8,
            max_decode_batch: 8,
            max_prompt: 64,
            max_seq: 128,
            ..Default::default()
            });
        let mut kv = KvCacheManager::new(1024, 16);
        for i in 0..64u64 {
            batcher.submit(Request::new(i, 0.0, vec![1; 16], 4));
        }
        let mut done = 0;
        while !batcher.all_done() && done < 10_000 {
            match batcher.next_work(&mut kv).unwrap() {
                flux::serving::batcher::Work::Prefill(ids) => {
                    let toks = vec![1i32; ids.len()];
                    batcher.complete_decode(&ids, &toks, &mut kv, 1.0)
                        .unwrap();
                }
                flux::serving::batcher::Work::Decode(ids) => {
                    let toks = vec![1i32; ids.len()];
                    batcher.complete_decode(&ids, &toks, &mut kv, 1.0)
                        .unwrap();
                }
                flux::serving::batcher::Work::Idle => break,
            }
            done += 1;
        }
        done
    });
}
