//! Bench for Fig. 4: PyTorch (non-overlap) vs TransformerEngine on
//! 8xH800 NVLink — regenerates the figure's series and times the two
//! simulators.
use flux::cost::arch::H800_NVLINK;
use flux::figures;
use flux::overlap::{baseline, medium};
use flux::util::bench::Bench;

fn main() {
    figures::print_table(&figures::fig04());
    let mut b = Bench::new();
    let p = figures::ag_problem(4096, 8);
    b.run("baseline::simulate AG m=4096 H800", || {
        baseline::simulate(&H800_NVLINK, &p)
    });
    b.run("medium::simulate   AG m=4096 H800", || {
        medium::simulate(&H800_NVLINK, &p, 7)
    });
}
