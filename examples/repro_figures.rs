//! Regenerate every table and figure of the paper's evaluation in one
//! run (the same generators back the per-figure benches).
//!
//! Run: `cargo run --release --example repro_figures`

fn main() {
    for t in flux::figures::all() {
        flux::figures::print_table(&t);
    }
}
