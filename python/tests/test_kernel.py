"""L1 correctness: Pallas fused kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/ranks; every property asserts allclose
against ref.py. These are the CORE correctness signal for the kernels the
whole stack is built on.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels as K
from compile.kernels import ref

HYP = dict(deadline=None, max_examples=20,
           suppress_health_check=[hypothesis.HealthCheck.too_slow])


def rand(rng, shape, dtype):
    x = rng.normal(0.0, 1.0, size=shape)
    return jnp.asarray(x.astype(np.float32)).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-4, atol=1e-4)


def assert_close(got, want, dtype):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **tol(dtype))


# ---------------------------------------------------------------------------
# GEMM + ReduceScatter
# ---------------------------------------------------------------------------

@hypothesis.settings(**HYP)
@hypothesis.given(
    n_tp=st.sampled_from([2, 4]),
    m_tiles_per_rank=st.integers(1, 3),
    k_tiles=st.integers(1, 3),
    n_tiles=st.integers(1, 3),
    swizzle=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_rs_matches_ref(n_tp, m_tiles_per_rank, k_tiles, n_tiles,
                             swizzle, dtype, seed):
    block = 16
    m = n_tp * m_tiles_per_rank * block
    k_local = k_tiles * block
    n = n_tiles * block
    rng = np.random.default_rng(seed)
    a = [rand(rng, (m, k_local), dtype) for _ in range(n_tp)]
    b = [rand(rng, (k_local, n), dtype) for _ in range(n_tp)]
    got = K.gemm_rs_fused(a, b, swizzle=swizzle,
                          block_m=block, block_n=block, block_k=block)
    want = ref.gemm_rs_ref(a, b)
    assert len(got) == n_tp
    for g, w in zip(got, want):
        assert g.shape == (m // n_tp, n)
        assert_close(g, w, dtype)


def test_gemm_rs_swizzle_is_numerically_invisible():
    """Swizzling permutes tile *traversal*, never values (§4.1)."""
    rng = np.random.default_rng(3)
    a = [rand(rng, (128, 32), jnp.float32) for _ in range(4)]
    b = [rand(rng, (32, 64), jnp.float32) for _ in range(4)]
    on = K.gemm_rs_fused(a, b, swizzle=True)
    off = K.gemm_rs_fused(a, b, swizzle=False)
    for x, y in zip(on, off):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_gemm_rs_scattered_layout():
    """Slot d of rank r's scattered output must equal rows [d*M/N,(d+1)*M/N)
    of rank r's full partial — the AlltoAll pre-image (Alg. 1)."""
    rng = np.random.default_rng(4)
    n_tp, m, kl, n = 4, 128, 32, 64
    a = [rand(rng, (m, kl), jnp.float32) for _ in range(n_tp)]
    b = [rand(rng, (kl, n), jnp.float32) for _ in range(n_tp)]
    per = m // n_tp
    for r in range(n_tp):
        scattered = K.flux_gemm_rs(a[r], b[r], rank=r, n_tp=n_tp)
        partial = ref.gemm_ref(a[r], b[r], out_dtype=jnp.float32)
        for d in range(n_tp):
            np.testing.assert_allclose(
                np.asarray(scattered[d]),
                np.asarray(partial[d * per:(d + 1) * per]),
                rtol=1e-5, atol=1e-5)


def test_gemm_rs_rejects_indivisible_m():
    rng = np.random.default_rng(0)
    a = rand(rng, (96, 32), jnp.float32)   # 96 rows, n_tp=4, block 32 → 3 tiles
    b = rand(rng, (32, 64), jnp.float32)
    with pytest.raises(AssertionError):
        K.flux_gemm_rs(a, b, rank=0, n_tp=4)


# ---------------------------------------------------------------------------
# AllGather + GEMM
# ---------------------------------------------------------------------------

@hypothesis.settings(**HYP)
@hypothesis.given(
    n_tp=st.sampled_from([2, 4]),
    m_tiles_per_rank=st.integers(1, 3),
    k_tiles=st.integers(1, 3),
    n_tiles=st.integers(1, 3),
    swizzle=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ag_gemm_matches_ref(n_tp, m_tiles_per_rank, k_tiles, n_tiles,
                             swizzle, dtype, seed):
    block = 16
    m = n_tp * m_tiles_per_rank * block
    k = k_tiles * block
    n_local = n_tiles * block
    rng = np.random.default_rng(seed)
    x = [rand(rng, (m // n_tp, k), dtype) for _ in range(n_tp)]
    w = [rand(rng, (k, n_local), dtype) for _ in range(n_tp)]
    got = K.ag_gemm_fused(x, w, swizzle=swizzle,
                          block_m=block, block_n=block, block_k=block)
    want = ref.ag_gemm_ref(x, w)
    for g, ww in zip(got, want):
        assert g.shape == (m, n_local)
        assert_close(g, ww, dtype)


def test_ag_gemm_equals_plain_gemm_on_gathered_input():
    """The fused kernel is a plain GEMM once data has arrived — fusion must
    not change the math (§3.2)."""
    rng = np.random.default_rng(5)
    x = [rand(rng, (32, 64), jnp.float32) for _ in range(4)]
    w = rand(rng, (64, 32), jnp.float32)
    agg = K.assemble_agg(x, 0)
    got = K.flux_ag_gemm(agg, w, rank=2, n_tp=4)
    want = ref.gemm_ref(agg, w, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Collective oracles are themselves self-consistent
# ---------------------------------------------------------------------------

@hypothesis.settings(**HYP)
@hypothesis.given(n_tp=st.sampled_from([2, 4, 8]), rows=st.integers(1, 4),
                  cols=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_rs_then_ag_is_allreduce(n_tp, rows, cols, seed):
    rng = np.random.default_rng(seed)
    parts = [rand(rng, (rows * n_tp, cols), jnp.float32)
             for _ in range(n_tp)]
    rs = ref.reduce_scatter_ref(parts, axis=0)
    back = ref.all_gather_ref(rs, axis=0)
    want = sum(np.asarray(p, np.float64) for p in parts)
    np.testing.assert_allclose(np.asarray(back), want, rtol=1e-4, atol=1e-4)


@hypothesis.settings(**HYP)
@hypothesis.given(n_tp=st.sampled_from([2, 4]), rows=st.integers(1, 3),
                  seed=st.integers(0, 2**31 - 1))
def test_alltoall_plus_reduce_equals_reduce_scatter(n_tp, rows, seed):
    """The §3.1 decoupling: RS == AlltoAll ∘ local-reduce."""
    rng = np.random.default_rng(seed)
    m, cols = rows * n_tp * 4, 8
    partials = [rand(rng, (m, cols), jnp.float32) for _ in range(n_tp)]
    # scattered[r][d] = rank r's partial rows owned by d
    per = m // n_tp
    scattered = [
        jnp.stack([p[d * per:(d + 1) * per] for d in range(n_tp)])
        for p in partials
    ]
    received = ref.all_to_all_ref(scattered)
    via_a2a = [ref.local_reduce_ref(rx) for rx in received]
    direct = ref.reduce_scatter_ref(partials, axis=0)
    for x, y in zip(via_a2a, direct):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Tile bookkeeping (swizzle / ring / schedule)
# ---------------------------------------------------------------------------

@hypothesis.settings(**HYP)
@hypothesis.given(n_tp=st.sampled_from([2, 4, 8]),
                  per=st.integers(1, 8), rank=st.integers(0, 7))
def test_swizzle_is_a_permutation(n_tp, per, rank):
    rank %= n_tp
    order = ref.swizzle_order(n_tp * per, rank, n_tp)
    assert sorted(order) == list(range(n_tp * per))


@hypothesis.settings(**HYP)
@hypothesis.given(n_tp=st.sampled_from([2, 4, 8]), per=st.integers(1, 8))
def test_swizzle_ranks_never_collide(n_tp, per):
    """At every traversal step the N ranks target N distinct destination
    devices — the §4.1 contention-avoidance invariant (Fig. 7)."""
    num = n_tp * per
    orders = [ref.swizzle_order(num, r, n_tp) for r in range(n_tp)]
    for step in range(num):
        dests = {ref.tile_dest(orders[r][step], num, n_tp)
                 for r in range(n_tp)}
        assert len(dests) == n_tp


def test_ring_order_paper_example():
    """§4.3: rank 5 of 8 communicates in order 6,7,0,1,2,3,4."""
    assert ref.ring_comm_order(5, 8) == [6, 7, 0, 1, 2, 3, 4]


@hypothesis.settings(**HYP)
@hypothesis.given(n_tp=st.sampled_from([2, 4, 8]),
                  tiles_per_rank=st.sampled_from([1, 2, 4]),
                  rank=st.integers(0, 7), pull=st.booleans())
def test_comm_schedule_covers_all_remote_rows(n_tp, tiles_per_rank, rank,
                                              pull):
    rank %= n_tp
    rows_per_rank = tiles_per_rank * 16
    m = n_tp * rows_per_rank
    sched = K.comm_tile_schedule(m, rank, n_tp, 16, pull=pull)
    covered = set()
    for t in sched:
        peer = t["src"] if pull else t["dst"]
        assert peer != rank, "local rows must not be transferred"
        rows = range(t["row0"], t["row0"] + t["rows"])
        assert all(r0 // rows_per_rank == peer for r0 in rows), \
            "tile rows must lie inside the peer's shard"
        assert covered.isdisjoint(rows), "no row transferred twice"
        covered.update(rows)
    want = set(range(m)) - set(range(rank * rows_per_rank,
                                     (rank + 1) * rows_per_rank))
    assert covered == want


def test_comm_schedule_signal_ids_unique():
    sched = K.comm_tile_schedule(256, 3, 8, 16)
    sigs = [t["signal"] for t in sched]
    assert len(sigs) == len(set(sigs))
