"""Artifact consistency: what aot.py exports must match what the Rust
runtime expects (manifest structure, weight shapes, HLO text health,
golden fixtures)."""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first",
)


def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_has_all_model_artifacts():
    m = manifest()
    for name in ["embed_prefill", "embed_decode", "attn_prefill",
                 "attn_decode", "mlp_prefill", "mlp_decode", "lm_head"]:
        assert name in m["artifacts"], name
        path = os.path.join(ART, m["artifacts"][name]["file"])
        assert os.path.getsize(path) > 100, name


def test_op_level_artifacts_per_rank():
    m = manifest()
    n_tp = m["op_level"]["n_tp"]
    for r in range(n_tp):
        assert f"flux_gemm_rs_r{r}" in m["artifacts"]
        assert f"flux_ag_gemm_r{r}" in m["artifacts"]


def test_no_elided_constants_in_hlo():
    """as_hlo_text elides big constants as `constant({...})`, which the
    Rust-side text parser cannot reconstruct — every such tensor must be
    a runtime parameter instead."""
    m = manifest()
    for name, a in m["artifacts"].items():
        with open(os.path.join(ART, a["file"])) as f:
            text = f.read()
        assert "constant({...})" not in text, (
            f"{name} bakes an elided constant; pass it as an argument"
        )


def test_weight_files_match_declared_shapes():
    m = manifest()
    for name, w in m["weights"].items():
        path = os.path.join(ART, w["file"])
        n = int(np.prod(w["shape"]))
        assert os.path.getsize(path) == 4 * n, (
            f"{name}: {os.path.getsize(path)} bytes != 4*{n}"
        )


def test_weight_shards_reassemble():
    """Rank shards of w1 must tile the full tensor (spot check l0)."""
    from compile import model as M
    m = manifest()
    cfg = M.ModelConfig.tiny()
    w_full = M.init_weights(cfg, seed=0)
    parts = []
    for r in range(m["config"]["n_tp"]):
        meta = m["weights"][f"l0.r{r}.w1"]
        arr = np.fromfile(os.path.join(ART, meta["file"]),
                          dtype=np.float32).reshape(meta["shape"])
        parts.append(arr)
    np.testing.assert_array_equal(
        np.concatenate(parts, axis=1), w_full["l0.w1"])


def test_golden_prefill_matches_regenerated_model():
    """golden_swizzle.json's prefill logits equal a fresh forward pass —
    guards against stale goldens after model edits."""
    import jax.numpy as jnp
    from compile import model as M
    with open(os.path.join(ART, "golden_swizzle.json")) as f:
        golden = json.load(f)
    if "prefill" not in golden:
        pytest.skip("hermetic (Rust-generated) golden has no prefill "
                    "section; run `make artifacts` with JAX to add it")
    g = golden["prefill"]
    cfg = M.ModelConfig.tiny()
    w = M.init_weights(cfg, seed=0)
    ids = np.asarray(g["ids"], np.int32)
    lens = np.asarray(g["lens"])
    seq = ids.shape[1]
    mask = (np.arange(seq)[None, :] < lens[:, None]).astype(np.float32)
    logits = M.full_forward(cfg, w, jnp.asarray(ids), jnp.asarray(mask))
    for b in range(ids.shape[0]):
        got = np.asarray(logits)[b, int(lens[b]) - 1]
        want = np.asarray(g["last_logits"][b], np.float32)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_manifest_config_consistent_with_tiny_model():
    from compile import model as M
    cfg = M.ModelConfig.tiny()
    c = manifest()["config"]
    assert c["d_model"] == cfg.d_model
    assert c["n_tp"] == cfg.n_tp
    assert c["hd_local"] == cfg.hd_local
    assert c["ff_local"] == cfg.ff_local
