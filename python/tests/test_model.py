"""L2 correctness: the TP decomposition of the transformer.

The central claim: per-rank partial executions + explicit collectives
produce bit-comparable results to the un-sharded model — this is the
algebra the whole FLUX system (and the Rust coordinator's execution plan)
relies on.
"""

import dataclasses

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np

from compile import model as M

HYP = dict(deadline=None, max_examples=8,
           suppress_health_check=[hypothesis.HealthCheck.too_slow])

CFG = M.ModelConfig.tiny()
W = M.init_weights(CFG, seed=0)


def _ids(rng, b, s):
    return jnp.asarray(rng.integers(0, CFG.vocab, size=(b, s)), jnp.int32)


def test_tp_forward_matches_full_forward():
    rng = np.random.default_rng(1)
    ids = _ids(rng, 2, 16)
    mask = jnp.ones((2, 16), jnp.float32)
    full = M.full_forward(CFG, W, ids, mask)
    tp = M.tp_forward(CFG, W, ids, mask)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tp),
                               rtol=2e-4, atol=2e-4)


@hypothesis.settings(**HYP)
@hypothesis.given(n_tp=st.sampled_from([1, 2, 4, 8]),
                  seed=st.integers(0, 2**31 - 1))
def test_tp_degree_is_numerically_irrelevant(n_tp, seed):
    """Changing N_TP must never change the math, only the partitioning."""
    cfg = dataclasses.replace(CFG, n_tp=n_tp)
    rng = np.random.default_rng(seed)
    ids = _ids(rng, 2, 8)
    mask = jnp.ones((2, 8), jnp.float32)
    full = M.full_forward(cfg, W, ids, mask)
    tp = M.tp_forward(cfg, W, ids, mask)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tp),
                               rtol=5e-4, atol=5e-4)


def test_attn_partials_sum_to_full_attention():
    """Summing rank partials == the row-parallel AllReduce (Megatron)."""
    rng = np.random.default_rng(2)
    b, s, d = 2, 16, CFG.d_model
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    mask = jnp.ones((b, s), jnp.float32)
    full_w = M.shard_full_layer(CFG, W, 0)
    want, _, _ = M.attn_prefill_partial(
        dataclasses.replace(CFG, n_tp=1), x, mask, *full_w[:4])
    parts = []
    for r in range(CFG.n_tp):
        sh = M.shard_layer(CFG, W, 0, r)
        p, _, _ = M.attn_prefill_partial(
            CFG, x, mask,
            jnp.asarray(sh["ln1_g"]), jnp.asarray(sh["ln1_b"]),
            jnp.asarray(sh["wqkv"]), jnp.asarray(sh["wo"]))
        parts.append(p)
    np.testing.assert_allclose(np.asarray(sum(parts)), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_mlp_partials_sum_to_full_mlp():
    rng = np.random.default_rng(3)
    b, s, d = 2, 8, CFG.d_model
    x = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
    full_w = M.shard_full_layer(CFG, W, 1)
    want = M.mlp_partial(dataclasses.replace(CFG, n_tp=1), x, *full_w[4:])
    parts = []
    for r in range(CFG.n_tp):
        sh = M.shard_layer(CFG, W, 1, r)
        parts.append(M.mlp_partial(
            CFG, x, jnp.asarray(sh["ln2_g"]), jnp.asarray(sh["ln2_b"]),
            jnp.asarray(sh["w1"]), jnp.asarray(sh["w2"])))
    np.testing.assert_allclose(np.asarray(sum(parts)), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_step_matches_prefill_extension():
    """Prefill s tokens, decode token s+1 == prefill s+1 tokens.

    This is the KV-cache correctness invariant the serving runtime needs.
    """
    rng = np.random.default_rng(4)
    b, s = 2, 8
    ids = _ids(rng, b, s + 1)
    mask_full = jnp.ones((b, s + 1), jnp.float32)
    want = M.full_forward(CFG, W, ids, mask_full)[:, s, :]  # logits@last

    # Manual prefill of s tokens + one decode step, TP-decomposed.
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = M.embed(ids[:, :s], positions, jnp.asarray(W["embed"]))
    mask = jnp.ones((b, s), jnp.float32)
    smax = CFG.max_seq
    caches = {}
    for l in range(CFG.n_layers):
        parts = []
        for r in range(CFG.n_tp):
            sh = M.shard_layer(CFG, W, l, r)
            p, k, v = M.attn_prefill_partial(
                CFG, x, mask,
                jnp.asarray(sh["ln1_g"]), jnp.asarray(sh["ln1_b"]),
                jnp.asarray(sh["wqkv"]), jnp.asarray(sh["wo"]))
            parts.append(p)
            kc = jnp.zeros((b, smax, CFG.hd_local), jnp.float32)
            vc = jnp.zeros_like(kc)
            kc = kc.at[:, :s].set(k)
            vc = vc.at[:, :s].set(v)
            caches[(l, r)] = (kc, vc)
        x = x + sum(parts)
        parts = []
        for r in range(CFG.n_tp):
            sh = M.shard_layer(CFG, W, l, r)
            parts.append(M.mlp_partial(
                CFG, x, jnp.asarray(sh["ln2_g"]), jnp.asarray(sh["ln2_b"]),
                jnp.asarray(sh["w1"]), jnp.asarray(sh["w2"])))
        x = x + sum(parts)

    # Decode token at position s.
    pos = jnp.full((b,), s, jnp.int32)
    x1 = M.embed(ids[:, s], pos, jnp.asarray(W["embed"]))[:, None, :]
    cl = jnp.full((b,), s, jnp.int32)
    for l in range(CFG.n_layers):
        parts = []
        for r in range(CFG.n_tp):
            sh = M.shard_layer(CFG, W, l, r)
            kc, vc = caches[(l, r)]
            p, kc, vc = M.attn_decode_partial(
                CFG, x1, kc, vc, cl,
                jnp.asarray(sh["ln1_g"]), jnp.asarray(sh["ln1_b"]),
                jnp.asarray(sh["wqkv"]), jnp.asarray(sh["wo"]))
            caches[(l, r)] = (kc, vc)
            parts.append(p)
        x1 = x1 + sum(parts)
        parts = []
        for r in range(CFG.n_tp):
            sh = M.shard_layer(CFG, W, l, r)
            parts.append(M.mlp_partial(
                CFG, x1, jnp.asarray(sh["ln2_g"]), jnp.asarray(sh["ln2_b"]),
                jnp.asarray(sh["w1"]), jnp.asarray(sh["w2"])))
        x1 = x1 + sum(parts)
    got = M.lm_head(x1[:, 0, :], jnp.asarray(W["ln_f_g"]),
                    jnp.asarray(W["ln_f_b"]), jnp.asarray(W["embed"]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


def test_variable_lengths_are_masked():
    """Padding positions must not influence valid positions' logits."""
    rng = np.random.default_rng(5)
    b, s = 2, 12
    ids = _ids(rng, b, s)
    lens = [7, 12]
    mask = jnp.asarray(
        (np.arange(s)[None, :] < np.array(lens)[:, None]).astype(np.float32))
    out = M.full_forward(CFG, W, ids, mask)
    # Changing tokens beyond the length must not change logits before it.
    ids2 = ids.at[0, 7:].set((ids[0, 7:] + 3) % CFG.vocab)
    out2 = M.full_forward(CFG, W, ids2, mask)
    np.testing.assert_allclose(np.asarray(out[0, :7]),
                               np.asarray(out2[0, :7]),
                               rtol=1e-5, atol=1e-5)


def test_sharding_partitions_weights_exactly():
    """Shards tile the full tensors: no overlap, nothing dropped."""
    d, ff = CFG.d_model, CFG.d_ff
    sh = [M.shard_layer(CFG, W, 0, r) for r in range(CFG.n_tp)]
    w1 = np.concatenate([np.asarray(s["w1"]) for s in sh], axis=1)
    np.testing.assert_array_equal(w1, W["l0.w1"])
    w2 = np.concatenate([np.asarray(s["w2"]) for s in sh], axis=0)
    np.testing.assert_array_equal(w2, W["l0.w2"])
    wo = np.concatenate([np.asarray(s["wo"]) for s in sh], axis=0)
    np.testing.assert_array_equal(wo, W["l0.wo"])
