# L1: Pallas kernels for FLUX's fused GEMM+communication hot-spots.
from . import ref  # noqa: F401
from .flux_ag_gemm import (  # noqa: F401
    ag_gemm_fused,
    assemble_agg,
    comm_tile_schedule,
    flux_ag_gemm,
)
from .flux_gemm_rs import flux_gemm_rs, gemm_rs_fused  # noqa: F401
