"""FLUX fused GEMM+ReduceScatter Pallas kernel (paper Alg. 1, §3.1, §4.2).

The CUDA original fuses the ReduceScatter's AlltoAll half into the GEMM
epilogue: each thread block computes one output tile and stores it directly
into the buffer of the rank that owns that M-block after the scatter,
visiting tiles in a rank-swizzled order to avoid memory-controller
contention (§4.1, Fig. 7).

TPU/Pallas adaptation (DESIGN.md §3): the thread-block tile becomes a grid
step; the epilogue's `GetOutput` pointer selection becomes the *output
BlockSpec index_map*, which routes logical tile (i, j) into a
[N_TP, M/N_TP, N] scattered layout whose leading axis is the destination
rank. Tile-coordinate swizzling is the grid→logical-tile permutation
applied consistently to the A and output index maps. The AlltoAll transport
and local reduction (the `Reduce` branch of Alg. 1) happen outside the
kernel in `gemm_rs_fused`, exactly mirroring the paper's decoupling of
ReduceScatter into AlltoAll + local reduce.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated against `ref.py` and real-TPU
performance is estimated analytically (EXPERIMENTS.md §Perf-L1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _swizzle_m(i, rank, n_tp, tiles_m, enabled: bool):
    """Grid index -> logical m-tile. Rank r starts at peer (r+1)'s block."""
    if not enabled:
        return i
    per = tiles_m // n_tp
    return (i + (rank + 1) % n_tp * per) % tiles_m


def _gemm_rs_kernel(a_ref, b_ref, o_ref, *, k_tiles):
    """Tiled matmul body with f32 accumulation into the scattered output.

    o_ref block shape is (1, bm, bn): the leading singleton is the
    destination-rank slot chosen by the output index_map (the `GetOutput`
    of Alg. 1).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    o_ref[0, :, :] += acc


def flux_gemm_rs(
    a,
    b,
    *,
    rank: int,
    n_tp: int,
    block_m: int = 32,
    block_n: int = 32,
    block_k: int = 32,
    swizzle: bool = True,
):
    """Run the fused GEMM+scatter kernel for one rank.

    a: [M, K_local] local activation, b: [K_local, N] local weight shard.
    Returns the *scattered* partial output [N_TP, M/N_TP, N] (f32): slot d
    holds the tiles this rank computed for destination rank d — i.e. what
    the CUDA kernel would have P2P-stored into rank d's memory.
    """
    m, k_dim = a.shape
    k_dim2, n = b.shape
    assert k_dim == k_dim2, f"inner dims mismatch: {k_dim} vs {k_dim2}"
    assert m % (n_tp * block_m) == 0, (
        f"M={m} must divide into N_TP={n_tp} x block_m={block_m} tiles"
    )
    assert n % block_n == 0 and k_dim % block_k == 0

    tiles_m = m // block_m
    tiles_n = n // block_n
    tiles_k = k_dim // block_k
    per_rank_tiles = tiles_m // n_tp

    def a_index(i, j, k):
        i_log = _swizzle_m(i, rank, n_tp, tiles_m, swizzle)
        return (i_log, k)

    def b_index(i, j, k):
        return (k, j)

    def out_index(i, j, k):
        i_log = _swizzle_m(i, rank, n_tp, tiles_m, swizzle)
        dest = i_log // per_rank_tiles  # TileCoord + GetOutput of Alg. 1
        local_i = i_log % per_rank_tiles
        return (dest, local_i, j)

    kernel = functools.partial(_gemm_rs_kernel, k_tiles=tiles_k)
    scattered = pl.pallas_call(
        kernel,
        grid=(tiles_m, tiles_n, tiles_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), a_index),
            pl.BlockSpec((block_k, block_n), b_index),
        ],
        out_specs=pl.BlockSpec((1, block_m, block_n), out_index),
        out_shape=jax.ShapeDtypeStruct((n_tp, m // n_tp, n), jnp.float32),
        interpret=True,
    )(a, b)
    return scattered


def gemm_rs_fused(a_shards, b_shards, *, swizzle: bool = True,
                  block_m: int = 32, block_n: int = 32, block_k: int = 32,
                  out_dtype=None):
    """Full fused GEMM+ReduceScatter across all simulated ranks.

    Runs the fused kernel on every rank, then performs the AlltoAll
    transport and the local reduction (§3.1's decoupling). Returns the
    per-rank [M/N_TP, N] ReduceScatter outputs.
    """
    n_tp = len(a_shards)
    scattered = [
        flux_gemm_rs(
            a_shards[r], b_shards[r], rank=r, n_tp=n_tp,
            block_m=block_m, block_n=block_n, block_k=block_k,
            swizzle=swizzle,
        )
        for r in range(n_tp)
    ]
    received = ref.all_to_all_ref(scattered)
    dt = out_dtype or a_shards[0].dtype
    return [ref.local_reduce_ref(rx).astype(dt) for rx in received]
