"""Pure-jnp reference oracles for the FLUX kernels.

These are the ground truth every Pallas kernel (and the Rust numeric twin)
is checked against. All collectives are expressed as explicit shard algebra
over a list of per-rank arrays — "rank r" is element r of the list — so the
algebraic identity (sharded == full computation) is testable on one host.

Shapes follow the paper's Fig. 2 (Megatron MLP with sharded activations):

  AG+GEMM   : x_r [M/N, K]   (M-sharded)   w_r [K, F/N]  (column shard)
              y_r = all_gather(x) @ w_r                → [M, F/N]
  GEMM+RS   : a_r [M, F/N]                 w_r [F/N, K] (row shard)
              partial_r = a_r @ w_r        → [M, K]
              out_r = sum_s partial_s[r-th M block]    → [M/N, K]
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(a, b, out_dtype=None):
    """Plain matmul with f32 accumulation — the `GEMM_non-split` of Eq. 1."""
    out = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    return out.astype(out_dtype or a.dtype)


def all_gather_ref(shards, axis=0):
    """AllGather over a list of per-rank shards → the full array.

    Every rank receives the same concatenation; we return it once.
    """
    return jnp.concatenate(list(shards), axis=axis)


def reduce_scatter_ref(partials, axis=0):
    """ReduceScatter over per-rank full-size partials.

    Returns a list: rank r gets the r-th block (along `axis`) of the
    elementwise sum of all partials. Accumulates in f32 like the kernels.
    """
    n = len(partials)
    total = partials[0].astype(jnp.float32)
    for p in partials[1:]:
        total = total + p.astype(jnp.float32)
    size = total.shape[axis]
    assert size % n == 0, f"axis {axis} of size {size} not divisible by {n}"
    block = size // n
    return [
        jnp.take(total, jnp.arange(r * block, (r + 1) * block), axis=axis)
        for r in range(n)
    ]


def all_to_all_ref(scattered):
    """AlltoAll of the paper's decoupled ReduceScatter (§3.1).

    `scattered[r]` is rank r's output laid out as [N, M/N, ...]: slot d is
    the tile block rank r computed *for* destination d. After AlltoAll,
    rank d holds [N, M/N, ...] where slot s came from source rank s.
    """
    n = len(scattered)
    return [
        jnp.stack([scattered[s][d] for s in range(n)], axis=0)
        for d in range(n)
    ]


def local_reduce_ref(received):
    """The local-reduction half of the decoupled ReduceScatter."""
    return jnp.sum(received.astype(jnp.float32), axis=0)


def gemm_rs_ref(a_shards, b_shards, out_dtype=None):
    """Fused GEMM+ReduceScatter oracle.

    a_shards[r]: [M, K_local], b_shards[r]: [K_local, N_out].
    Returns a list of per-rank [M/N, N_out] RS outputs.
    """
    partials = [
        gemm_ref(a, b, out_dtype=jnp.float32)
        for a, b in zip(a_shards, b_shards)
    ]
    outs = reduce_scatter_ref(partials, axis=0)
    dt = out_dtype or a_shards[0].dtype
    return [o.astype(dt) for o in outs]


def ag_gemm_ref(x_shards, w_locals, out_dtype=None):
    """Fused AllGather+GEMM oracle.

    x_shards[r]: [M/N, K], w_locals[r]: [K, N_local].
    Returns a list of per-rank [M, N_local] outputs.
    """
    x_full = all_gather_ref(x_shards, axis=0)
    return [gemm_ref(x_full, w, out_dtype=out_dtype) for w in w_locals]


# ---------------------------------------------------------------------------
# Tile bookkeeping twins (mirrored in rust/src/overlap/tiles.rs). These are
# pure index math; tested for equivalence against the Rust side via the
# golden file artifacts/golden_swizzle.json (emitted by aot.py).
# ---------------------------------------------------------------------------

def swizzle_order(num_tiles: int, rank: int, n_tp: int):
    """FLUX tile-coordinate swizzling (§4.1).

    Rank r starts its tile traversal at its *next* peer's block so that at
    any instant the N ranks write to N different destination devices,
    avoiding memory-controller contention (Fig. 7).
    """
    assert num_tiles % n_tp == 0
    per = num_tiles // n_tp
    start = ((rank + 1) % n_tp) * per
    return [(start + i) % num_tiles for i in range(num_tiles)]


def ring_comm_order(rank: int, n_tp: int):
    """Host-side communication order on NVLink (§4.3): ring starting after
    the local rank, e.g. rank 5 of 8 → [6, 7, 0, 1, 2, 3, 4]."""
    return [(rank + 1 + i) % n_tp for i in range(n_tp - 1)]


def tile_dest(tile_m: int, tiles_m: int, n_tp: int) -> int:
    """Destination rank of an output row-tile in GEMM+ReduceScatter: the
    owner of that M block after the scatter."""
    assert tiles_m % n_tp == 0
    return tile_m // (tiles_m // n_tp)


def mlp_tp_ref(x_shards, w1_locals, w2_locals, act=None):
    """The whole Fig.-2 MLP: AG+GEMM → activation → GEMM+RS.

    x_shards[r]: [M/N, K]; w1_locals[r]: [K, F/N]; w2_locals[r]: [F/N, K].
    Returns per-rank [M/N, K] outputs.
    """
    if act is None:
        act = lambda v: jnp.where(v > 0, v, 0.0)  # ReLU default
    h = ag_gemm_ref(x_shards, w1_locals, out_dtype=jnp.float32)
    h = [act(hi) for hi in h]
    return gemm_rs_ref(h, w2_locals, out_dtype=x_shards[0].dtype)
