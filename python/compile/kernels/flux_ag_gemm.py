"""FLUX fused AllGather+GEMM Pallas kernel (paper Alg. 2/3, §3.2, §4.3).

The CUDA original fuses only the *wait* half of the AllGather into the GEMM
prologue: a host loop transfers communication tiles (pull- or push-based)
and sets signals; every GEMM thread block spins on the signal guarding the
A-tile it consumes. Signals for local tiles are preset, so local tiles
compute immediately while remote tiles stream in.

TPU/Pallas adaptation (DESIGN.md §3): a dataflow machine has no spinning —
instead the kernel consumes the aggregated operand with a *grid traversal
order* chosen to match signal-arrival order: the local rank's M-block
first, then peers in ring order (the §4.3 NVLink communication order).
That traversal is the same TileCoord swizzle as Alg. 2, expressed in the
BlockSpec index maps. Signal-wait latency is modeled where it is observable
on this substrate: in the L3 discrete-event simulator
(rust/src/overlap/flux.rs + signals.rs).

The host half (Alg. 3) is mirrored by `comm_tile_schedule` below, which is
also the golden reference for the Rust scheduler's transfer order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _swizzle_m_local_first(i, rank, n_tp, tiles_m, enabled: bool):
    """Grid index -> logical m-tile, local rank's block first then ring.

    Mirrors the preset-local-signals behaviour: tiles whose data is already
    resident are computed first; remote tiles follow in the order the §4.3
    ring schedule delivers them (rank+1, rank+2, ...).
    """
    if not enabled:
        return i
    per = tiles_m // n_tp
    return (i + rank * per) % tiles_m


def _ag_gemm_kernel(a_ref, b_ref, o_ref):
    """Plain tiled-matmul body; the AllGather shows up only in index maps."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32)


def flux_ag_gemm(
    a_agg,
    b,
    *,
    rank: int,
    n_tp: int,
    block_m: int = 32,
    block_n: int = 32,
    block_k: int = 32,
    swizzle: bool = True,
):
    """Fused AllGather+GEMM for one rank: C = A_agg @ B_local.

    a_agg: [M, K] the aggregated activation buffer (assembled by the host
    transfer loop), b: [K, N_local]. Returns [M, N_local] (f32).
    """
    m, k_dim = a_agg.shape
    k_dim2, n = b.shape
    assert k_dim == k_dim2
    assert m % (n_tp * block_m) == 0, (
        f"M={m} must divide into N_TP={n_tp} x block_m={block_m} tiles"
    )
    assert n % block_n == 0 and k_dim % block_k == 0

    tiles_m = m // block_m
    tiles_n = n // block_n
    tiles_k = k_dim // block_k

    def a_index(i, j, k):
        return (_swizzle_m_local_first(i, rank, n_tp, tiles_m, swizzle), k)

    def b_index(i, j, k):
        return (k, j)

    def out_index(i, j, k):
        return (_swizzle_m_local_first(i, rank, n_tp, tiles_m, swizzle), j)

    out = pl.pallas_call(
        _ag_gemm_kernel,
        grid=(tiles_m, tiles_n, tiles_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), a_index),
            pl.BlockSpec((block_k, block_n), b_index),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), out_index),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a_agg, b)
    return out


def comm_tile_schedule(m: int, rank: int, n_tp: int, comm_tile_rows: int,
                       pull: bool = True):
    """Host-side transfer schedule of Alg. 3 — the golden twin of
    rust/src/overlap/tiles.rs::comm_schedule.

    Returns a list of transfer dicts in issue order. Each communication
    tile is `comm_tile_rows` rows of the aggregated A buffer. Peers are
    visited in ring order after the local rank (§4.3); within a peer,
    tiles go in ascending row order. Local rows need no transfer (their
    signals are preset).

    pull: rank fetches from peer (src=peer, dst=rank, signal local);
    push semantics are produced by the peer running the same schedule, so
    here the flag only tags the record (bandwidth asymmetry is modeled in
    the L3 simulator).
    """
    assert m % n_tp == 0
    rows_per_rank = m // n_tp
    assert rows_per_rank % comm_tile_rows == 0, (
        f"rows/rank {rows_per_rank} not divisible by comm tile "
        f"{comm_tile_rows}"
    )
    tiles_per_rank = rows_per_rank // comm_tile_rows
    schedule = []
    for peer in ref.ring_comm_order(rank, n_tp):
        for t in range(tiles_per_rank):
            row0 = peer * rows_per_rank + t * comm_tile_rows
            schedule.append({
                "src": peer if pull else rank,
                "dst": rank if pull else peer,
                "row0": row0,
                "rows": comm_tile_rows,
                "pull": pull,
                "signal": peer * tiles_per_rank + t,
            })
    return schedule


def assemble_agg(x_shards, rank: int):
    """Assemble the aggregated A buffer the way the host loop would.

    Layout is always rank-major (row block r belongs to rank r) regardless
    of arrival order — arrival order changes *timing*, not layout.
    """
    del rank  # layout is rank-invariant; arg kept for signature symmetry
    return ref.all_gather_ref(x_shards, axis=0)


def ag_gemm_fused(x_shards, w_locals, *, swizzle: bool = True,
                  block_m: int = 32, block_n: int = 32, block_k: int = 32,
                  out_dtype=None):
    """Full fused AllGather+GEMM across all simulated ranks.

    x_shards[r]: [M/N_TP, K]; w_locals[r]: [K, N_local].
    Returns per-rank [M, N_local] outputs.
    """
    n_tp = len(x_shards)
    dt = out_dtype or x_shards[0].dtype
    outs = []
    for r in range(n_tp):
        a_agg = assemble_agg(x_shards, r)
        outs.append(
            flux_ag_gemm(
                a_agg, w_locals[r], rank=r, n_tp=n_tp,
                block_m=block_m, block_n=block_n, block_k=block_k,
                swizzle=swizzle,
            ).astype(dt)
        )
    return outs
