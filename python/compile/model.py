"""L2: Megatron-style tensor-parallel transformer in JAX.

This is the model whose TP communication FLUX overlaps. The sharding
follows the paper's Fig. 2 (and Megatron-LM [24] for attention):

  * attention: heads column-sharded across ranks (wqkv: [d, 3*d/N]),
    output projection row-sharded (wo: [d/N, d]) → the per-rank output is
    a *partial sum* that the coordinator combines (ReduceScatter+AllGather
    == AllReduce), which is exactly where the fused GEMM+RS kernel plugs
    in.
  * MLP: w1 column-sharded ([d, ff/N], AG+GEMM), w2 row-sharded
    ([ff/N, d], GEMM+RS).

Everything here is build-time Python: `aot.py` lowers the per-rank partial
functions to HLO text, and the Rust coordinator (rust/src/serving) runs
them per rank and performs the collectives between them. `full_forward`
(no TP) is the oracle the decomposed execution is checked against, both in
pytest and — via exported artifacts — in Rust integration tests.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A (tiny) GPT-style decoder config.

    `tiny()` is the config served end-to-end in examples/serve_e2e.rs; the
    paper-scale GPT-3 175B / Llama-2 70B configs live in
    rust/src/model/configs.rs where only their *cost* is needed.
    """

    vocab: int = 512
    d_model: int = 256
    n_heads: int = 8
    n_layers: int = 4
    d_ff: int = 1024
    max_seq: int = 128
    n_tp: int = 4

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def heads_local(self) -> int:
        assert self.n_heads % self.n_tp == 0
        return self.n_heads // self.n_tp

    @property
    def hd_local(self) -> int:
        """Per-rank width of the sharded attention projections."""
        return self.heads_local * self.head_dim

    @property
    def ff_local(self) -> int:
        assert self.d_ff % self.n_tp == 0
        return self.d_ff // self.n_tp

    @staticmethod
    def tiny() -> "ModelConfig":
        return ModelConfig()


# ---------------------------------------------------------------------------
# Weights
# ---------------------------------------------------------------------------

def init_weights(cfg: ModelConfig, seed: int = 0) -> dict:
    """Deterministic weight init (numpy PRNG so Rust tests can rely on the
    exported .bin files being stable across runs)."""
    rng = np.random.default_rng(seed)
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab

    def norm(*shape, scale=None):
        s = scale if scale is not None else 1.0 / math.sqrt(shape[0])
        return rng.normal(0.0, s, size=shape).astype(np.float32)

    w = {
        "embed": norm(v, d, scale=0.02),
        "ln_f_g": np.ones(d, np.float32),
        "ln_f_b": np.zeros(d, np.float32),
    }
    for l in range(cfg.n_layers):
        w[f"l{l}.ln1_g"] = np.ones(d, np.float32)
        w[f"l{l}.ln1_b"] = np.zeros(d, np.float32)
        w[f"l{l}.wqkv"] = norm(d, 3 * d)
        w[f"l{l}.wo"] = norm(d, d)
        w[f"l{l}.ln2_g"] = np.ones(d, np.float32)
        w[f"l{l}.ln2_b"] = np.zeros(d, np.float32)
        w[f"l{l}.w1"] = norm(d, ff)
        w[f"l{l}.w2"] = norm(ff, d)
    return w


def shard_layer(cfg: ModelConfig, w: dict, layer: int, rank: int) -> dict:
    """Extract rank `rank`'s TP shard of one layer's weights.

    wqkv is sharded per-projection (the q, k and v blocks are each column
    sharded) so that rank r owns heads [r*hl, (r+1)*hl) of all three.
    """
    d = cfg.d_model
    hl = cfg.hd_local
    lo, hi = rank * hl, (rank + 1) * hl
    wqkv = w[f"l{layer}.wqkv"]
    q, k, v = wqkv[:, 0:d], wqkv[:, d:2 * d], wqkv[:, 2 * d:3 * d]
    fl = cfg.ff_local
    return {
        "ln1_g": w[f"l{layer}.ln1_g"],
        "ln1_b": w[f"l{layer}.ln1_b"],
        "wqkv": np.concatenate([q[:, lo:hi], k[:, lo:hi], v[:, lo:hi]],
                               axis=1),
        "wo": w[f"l{layer}.wo"][lo:hi, :],
        "ln2_g": w[f"l{layer}.ln2_g"],
        "ln2_b": w[f"l{layer}.ln2_b"],
        "w1": w[f"l{layer}.w1"][:, rank * fl:(rank + 1) * fl],
        "w2": w[f"l{layer}.w2"][rank * fl:(rank + 1) * fl, :],
    }


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(
        math.sqrt(2.0 / math.pi) * (x + 0.044715 * jnp.power(x, 3))))


def sin_pos_encoding(positions, d_model: int):
    """Sinusoidal positions — computed, not learned, so the embed artifact
    needs no extra weight tensor. positions: [...,] int32."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def embed(ids, positions, embed_w):
    """Token + positional embedding. ids: [...,] int32."""
    return embed_w[ids] + sin_pos_encoding(positions, embed_w.shape[1])


def _attention(q, k, v, mask):
    """q: [B, Hl, Sq, hd]; k, v: [B, Hl, Sk, hd]; mask: [B, 1, Sq, Sk]."""
    hd = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def _split_heads(x, n_heads):
    b, s, hw = x.shape
    return x.reshape(b, s, n_heads, hw // n_heads).transpose(0, 2, 1, 3)


def _merge_heads(x):
    b, h, s, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * hd)


# ---------------------------------------------------------------------------
# Per-rank partial functions (what aot.py exports)
# ---------------------------------------------------------------------------

def attn_prefill_partial(cfg: ModelConfig, x, len_mask, ln_g, ln_b, wqkv,
                         wo):
    """Rank-local attention over a full prompt.

    x: [B, S, d] (gathered input — every rank holds it, the AllGather
    having been done by the coordinator), len_mask: [B, S] 1/0 validity.
    Returns (partial [B, S, d], k_cache [B, S, hd_l], v_cache [B, S, hd_l]).
    The partial is this rank's *summand* of the attention output: summing
    over ranks == the row-parallel wo matmul's AllReduce.
    """
    b, s, d = x.shape
    hl = cfg.hd_local
    h = layer_norm(x, ln_g, ln_b)
    qkv = jnp.matmul(h, wqkv, preferred_element_type=jnp.float32)
    q, k, v = qkv[..., :hl], qkv[..., hl:2 * hl], qkv[..., 2 * hl:]
    qh = _split_heads(q, cfg.heads_local)
    kh = _split_heads(k, cfg.heads_local)
    vh = _split_heads(v, cfg.heads_local)
    causal = jnp.tril(jnp.ones((s, s), bool))[None, None]
    valid = (len_mask[:, None, None, :] > 0)
    out = _attention(qh, kh, vh, causal & valid)
    partial = jnp.matmul(_merge_heads(out), wo,
                         preferred_element_type=jnp.float32)
    return partial, k, v


def attn_decode_partial(cfg: ModelConfig, x, k_cache, v_cache, cache_len,
                        ln_g, ln_b, wqkv, wo):
    """Rank-local attention for one decode step with a KV cache.

    x: [B, 1, d]; k_cache/v_cache: [B, Smax, hd_l]; cache_len: [B] int32 —
    the number of valid cache positions *before* this token.
    Returns (partial [B, 1, d], k_cache', v_cache') with the new token's
    K/V written functionally at position cache_len.
    """
    b, _, d = x.shape
    hl = cfg.hd_local
    smax = k_cache.shape[1]
    h = layer_norm(x, ln_g, ln_b)
    qkv = jnp.matmul(h, wqkv, preferred_element_type=jnp.float32)
    q, k, v = qkv[..., :hl], qkv[..., hl:2 * hl], qkv[..., 2 * hl:]

    # Functional scatter of the new K/V at each sequence's cache_len.
    pos = jnp.arange(smax)[None, :, None]                    # [1, Smax, 1]
    at = (pos == cache_len[:, None, None])                   # [B, Smax, 1]
    k_cache = jnp.where(at, k, k_cache)
    v_cache = jnp.where(at, v, v_cache)

    qh = _split_heads(q, cfg.heads_local)                    # [B,Hl,1,hd]
    kh = _split_heads(k_cache, cfg.heads_local)              # [B,Hl,Smax,hd]
    vh = _split_heads(v_cache, cfg.heads_local)
    valid = (jnp.arange(smax)[None, None, None, :]
             <= cache_len[:, None, None, None])              # incl. new tok
    out = _attention(qh, kh, vh, valid)
    partial = jnp.matmul(_merge_heads(out), wo,
                         preferred_element_type=jnp.float32)
    return partial, k_cache, v_cache


def mlp_partial(cfg: ModelConfig, x, ln_g, ln_b, w1, w2):
    """Rank-local MLP partial: LN → x@w1_local → gelu → @w2_local.

    The w1 matmul is the AG+GEMM of Fig. 2 (x arrives gathered); the w2
    matmul produces the partial that the GEMM+RS (+AG) combines.
    x: [B, S, d]; w1: [d, ff_l]; w2: [ff_l, d] → [B, S, d].
    """
    del cfg
    h = layer_norm(x, ln_g, ln_b)
    h = jnp.matmul(h, w1, preferred_element_type=jnp.float32)
    h = gelu(h)
    return jnp.matmul(h, w2, preferred_element_type=jnp.float32)


def lm_head(x, ln_g, ln_b, embed_w):
    """Final LN + tied-embedding projection. x: [B, d] → logits [B, vocab]."""
    h = layer_norm(x, ln_g, ln_b)
    return jnp.matmul(h, embed_w.T, preferred_element_type=jnp.float32)


# ---------------------------------------------------------------------------
# Full references (the oracles)
# ---------------------------------------------------------------------------

def full_forward(cfg: ModelConfig, w: dict, ids, len_mask):
    """Non-TP full-model prefill → logits for every position.

    ids: [B, S] int32; len_mask: [B, S]. Returns [B, S, vocab] f32.
    """
    b, s = ids.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = embed(ids, positions, jnp.asarray(w["embed"]))
    for l in range(cfg.n_layers):
        # TP with N=1: a single "rank" holding the whole layer.
        full = shard_full_layer(cfg, w, l)
        a, _, _ = attn_prefill_partial(
            _as_tp1(cfg), x, len_mask, *full[:4])
        x = x + a
        x = x + mlp_partial(_as_tp1(cfg), x, *full[4:])
    return lm_head(x, jnp.asarray(w["ln_f_g"]), jnp.asarray(w["ln_f_b"]),
                   jnp.asarray(w["embed"]))


def shard_full_layer(cfg: ModelConfig, w: dict, layer: int):
    """Layer weights as one un-sharded 'rank' (tuple in artifact order)."""
    return (
        jnp.asarray(w[f"l{layer}.ln1_g"]), jnp.asarray(w[f"l{layer}.ln1_b"]),
        jnp.asarray(w[f"l{layer}.wqkv"]), jnp.asarray(w[f"l{layer}.wo"]),
        jnp.asarray(w[f"l{layer}.ln2_g"]), jnp.asarray(w[f"l{layer}.ln2_b"]),
        jnp.asarray(w[f"l{layer}.w1"]), jnp.asarray(w[f"l{layer}.w2"]),
    )


def _as_tp1(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, n_tp=1)


def tp_forward(cfg: ModelConfig, w: dict, ids, len_mask):
    """TP-decomposed prefill: per-rank partials + explicit AllReduce.

    This is *exactly* the execution the Rust coordinator performs over the
    exported artifacts, kept in Python so pytest can assert
    tp_forward == full_forward before anything is exported.
    """
    b, s = ids.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = embed(ids, positions, jnp.asarray(w["embed"]))
    shards = [[shard_layer(cfg, w, l, r) for r in range(cfg.n_tp)]
              for l in range(cfg.n_layers)]
    for l in range(cfg.n_layers):
        partials = [
            attn_prefill_partial(
                cfg, x, len_mask,
                jnp.asarray(sh["ln1_g"]), jnp.asarray(sh["ln1_b"]),
                jnp.asarray(sh["wqkv"]), jnp.asarray(sh["wo"]))[0]
            for sh in shards[l]
        ]
        x = x + sum(partials)          # AllReduce == RS + AG
        partials = [
            mlp_partial(cfg, x,
                        jnp.asarray(sh["ln2_g"]), jnp.asarray(sh["ln2_b"]),
                        jnp.asarray(sh["w1"]), jnp.asarray(sh["w2"]))
            for sh in shards[l]
        ]
        x = x + sum(partials)
    return lm_head(x, jnp.asarray(w["ln_f_g"]), jnp.asarray(w["ln_f_b"]),
                   jnp.asarray(w["embed"]))
