"""AOT exporter: lower every build-time computation to HLO *text*.

Run once at build time (`make artifacts`); the Rust coordinator is fully
self-contained afterwards. Interchange is HLO text, NOT a serialized
HloModuleProto — jax >= 0.5 emits protos with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Emitted into artifacts/:
  * op-level kernels (quickstart + Rust integration tests):
      gemm_m{M}k{K}n{N}.hlo.txt          plain GEMM (the Eq.-1 baseline)
      flux_gemm_rs_r{r}.hlo.txt          fused GEMM+scatter, per rank
      flux_ag_gemm_r{r}.hlo.txt          fused AG+GEMM, per rank
  * model-level per-rank partials (serving hot path):
      embed_prefill / embed_decode / attn_prefill / attn_decode /
      mlp_prefill / mlp_decode / lm_head  (.hlo.txt each)
  * weights/*.bin   f32 little-endian tensors, per rank-shard
  * manifest.json   config + tensor index + artifact signatures
  * golden_swizzle.json   tile-order golden data for the Rust twin tests
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref
from .kernels.flux_ag_gemm import comm_tile_schedule, flux_ag_gemm
from .kernels.flux_gemm_rs import flux_gemm_rs

# Op-level artifact shapes (modest so the HLO text stays small; the
# paper-scale shapes are exercised by the cost model, not by CPU numerics).
OP_NTP = 4
OP_M, OP_K, OP_N = 128, 256, 128
OP_BLOCK = 32

# Serving shapes (static; the router pads batches to these).
BATCH = 4
SEQ = 64
SMAX = 128


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"artifacts": {}, "weights": {}}
        os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    def lower(self, name: str, fn, *specs):
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        self.manifest["artifacts"][name] = {
            "file": path,
            "inputs": [[list(s.shape), str(s.dtype)] for s in specs],
        }
        print(f"  {path:36s} {len(text):>9d} chars")

    def tensor(self, name: str, arr: np.ndarray):
        arr = np.ascontiguousarray(arr, dtype=np.float32)
        path = os.path.join("weights", name.replace("/", "_") + ".bin")
        arr.tofile(os.path.join(self.out_dir, path))
        self.manifest["weights"][name] = {
            "file": path,
            "shape": list(arr.shape),
        }

    def finish(self, cfg: M.ModelConfig):
        self.manifest["config"] = {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_heads": cfg.n_heads, "n_layers": cfg.n_layers,
            "d_ff": cfg.d_ff, "max_seq": cfg.max_seq, "n_tp": cfg.n_tp,
            "batch": BATCH, "seq": SEQ, "smax": SMAX,
            "hd_local": cfg.hd_local, "ff_local": cfg.ff_local,
        }
        self.manifest["op_level"] = {
            "n_tp": OP_NTP, "m": OP_M, "k": OP_K, "n": OP_N,
            "block": OP_BLOCK,
        }
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)


def export_op_level(ex: Exporter):
    """Kernels for quickstart + Rust runtime integration tests."""
    # Plain GEMM — the `GEMM_non-split` baseline of Eq. 1.
    ex.lower(f"gemm_m{OP_M}k{OP_K}n{OP_N}",
             lambda a, b: (ref.gemm_ref(a, b),),
             spec((OP_M, OP_K)), spec((OP_K, OP_N)))

    kl = OP_K // OP_NTP  # GEMM+RS input is K-sharded
    for r in range(OP_NTP):
        ex.lower(
            f"flux_gemm_rs_r{r}",
            functools.partial(
                lambda a, b, rank: (flux_gemm_rs(
                    a, b, rank=rank, n_tp=OP_NTP,
                    block_m=OP_BLOCK, block_n=OP_BLOCK, block_k=OP_BLOCK),),
                rank=r),
            spec((OP_M, kl)), spec((kl, OP_N)))

    nl = OP_N // OP_NTP  # AG+GEMM weight is N-sharded
    for r in range(OP_NTP):
        ex.lower(
            f"flux_ag_gemm_r{r}",
            functools.partial(
                lambda a, b, rank: (flux_ag_gemm(
                    a, b, rank=rank, n_tp=OP_NTP,
                    block_m=OP_BLOCK, block_n=OP_BLOCK, block_k=OP_BLOCK),),
                rank=r),
            spec((OP_M, OP_K)), spec((OP_K, nl)))


def export_model(ex: Exporter, cfg: M.ModelConfig, weights: dict):
    d, hl, fl = cfg.d_model, cfg.hd_local, cfg.ff_local
    i32 = jnp.int32

    # The embedding table is a runtime parameter: large constants are
    # elided to `constant({...})` by as_hlo_text and would not round-trip
    # through the text parser on the Rust side.
    ex.lower("embed_prefill",
             lambda ids, pos, emb: (M.embed(ids, pos, emb),),
             spec((BATCH, SEQ), i32), spec((BATCH, SEQ), i32),
             spec((cfg.vocab, d)))

    ex.lower("embed_decode",
             lambda ids, pos, emb: (M.embed(ids, pos, emb)[:, None, :],),
             spec((BATCH,), i32), spec((BATCH,), i32),
             spec((cfg.vocab, d)))

    ex.lower("attn_prefill",
             lambda x, mask, g, b, wqkv, wo: M.attn_prefill_partial(
                 cfg, x, mask, g, b, wqkv, wo),
             spec((BATCH, SEQ, d)), spec((BATCH, SEQ)),
             spec((d,)), spec((d,)), spec((d, 3 * hl)), spec((hl, d)))

    ex.lower("attn_decode",
             lambda x, kc, vc, cl, g, b, wqkv, wo: M.attn_decode_partial(
                 cfg, x, kc, vc, cl, g, b, wqkv, wo),
             spec((BATCH, 1, d)), spec((BATCH, SMAX, hl)),
             spec((BATCH, SMAX, hl)), spec((BATCH,), i32),
             spec((d,)), spec((d,)), spec((d, 3 * hl)), spec((hl, d)))

    ex.lower("mlp_prefill",
             lambda x, g, b, w1, w2: (M.mlp_partial(cfg, x, g, b, w1, w2),),
             spec((BATCH, SEQ, d)), spec((d,)), spec((d,)),
             spec((d, fl)), spec((fl, d)))

    ex.lower("mlp_decode",
             lambda x, g, b, w1, w2: (M.mlp_partial(cfg, x, g, b, w1, w2),),
             spec((BATCH, 1, d)), spec((d,)), spec((d,)),
             spec((d, fl)), spec((fl, d)))

    ex.lower("lm_head",
             lambda x, g, b, emb: (M.lm_head(x, g, b, emb),),
             spec((BATCH, d)), spec((d,)), spec((d,)),
             spec((cfg.vocab, d)))


def export_weights(ex: Exporter, cfg: M.ModelConfig, weights: dict):
    """Per-rank shards, named the way rust/src/serving/weights.rs loads
    them: l{layer}.r{rank}.{tensor}."""
    for l in range(cfg.n_layers):
        for r in range(cfg.n_tp):
            sh = M.shard_layer(cfg, weights, l, r)
            for k, v in sh.items():
                ex.tensor(f"l{l}.r{r}.{k}", v)
    ex.tensor("ln_f_g", weights["ln_f_g"])
    ex.tensor("ln_f_b", weights["ln_f_b"])
    # Runtime parameter of embed_prefill / embed_decode / lm_head.
    ex.tensor("embed", weights["embed"])


def export_goldens(out_dir: str, cfg: M.ModelConfig, weights: dict):
    """Golden cross-language fixtures for the Rust twins."""
    golden = {"swizzle": [], "ring": [], "comm_sched": []}
    for n_tp in (2, 4, 8):
        for rank in range(n_tp):
            golden["swizzle"].append({
                "num_tiles": 4 * n_tp, "rank": rank, "n_tp": n_tp,
                "order": ref.swizzle_order(4 * n_tp, rank, n_tp),
            })
            golden["ring"].append({
                "rank": rank, "n_tp": n_tp,
                "order": ref.ring_comm_order(rank, n_tp),
            })
    for m, n_tp, rows in ((128, 4, 16), (256, 8, 32), (64, 2, 32)):
        for rank in range(n_tp):
            golden["comm_sched"].append({
                "m": m, "rank": rank, "n_tp": n_tp, "rows": rows,
                "schedule": comm_tile_schedule(
                    m, rank, n_tp, rows),
            })
    # A full-forward golden for the Rust e2e serving test.
    rng = np.random.default_rng(7)
    ids = rng.integers(0, cfg.vocab, size=(BATCH, SEQ)).astype(np.int32)
    lens = np.asarray([SEQ, SEQ // 2, 10, 1], np.int64)[:BATCH]
    mask = (np.arange(SEQ)[None, :] < lens[:, None]).astype(np.float32)
    logits = M.full_forward(cfg, weights, jnp.asarray(ids),
                            jnp.asarray(mask))
    # Keep the golden small: logits at each sequence's last valid position.
    last = np.asarray(
        [np.asarray(logits)[b, int(lens[b]) - 1] for b in range(BATCH)])
    golden["prefill"] = {
        "ids": ids.tolist(), "lens": lens.tolist(),
        "last_logits": [[float(v) for v in row] for row in last],
    }
    with open(os.path.join(out_dir, "golden_swizzle.json"), "w") as f:
        json.dump(golden, f)
    print(f"  golden_swizzle.json                  "
          f"{os.path.getsize(os.path.join(out_dir, 'golden_swizzle.json')):>9d} bytes")


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts/model.hlo.txt",
                   help="marker path; artifacts land in its directory")
    args = p.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(out_dir, exist_ok=True)

    cfg = M.ModelConfig.tiny()
    weights = M.init_weights(cfg, seed=0)

    ex = Exporter(out_dir)
    print("op-level kernels:")
    export_op_level(ex)
    print("model partials:")
    export_model(ex, cfg, weights)
    export_weights(ex, cfg, weights)
    ex.finish(cfg)
    export_goldens(out_dir, cfg, weights)

    # Marker file so Make's dependency tracking has a single target.
    with open(args.out, "w") as f:
        f.write("flux artifacts complete\n")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
