# FLUX build entry points.
#
# `make artifacts` resolves the cross-language artifacts two ways:
#   * JAX available  -> python/compile/aot.py exports the full set (HLO
#     text, weight shards, manifest.json, golden_swizzle.json with the
#     prefill logits golden);
#   * JAX missing    -> the hermetic Rust generator rewrites
#     artifacts/golden_swizzle.json only (same bytes as the checked-in
#     copy), which is everything `cargo test` needs.

ARTIFACTS := artifacts

.PHONY: artifacts test bench bench-ci fmt lint clean

artifacts:
	@if python3 -c "import jax" >/dev/null 2>&1; then \
		echo "JAX found: exporting the full AOT artifact set"; \
		cd python && python3 -m compile.aot --out ../$(ARTIFACTS)/model.hlo.txt; \
	else \
		echo "JAX not found: writing hermetic goldens via the Rust generator"; \
		cargo run --quiet --manifest-path rust/Cargo.toml --bin flux -- \
			gen-goldens --out $(ARTIFACTS)/golden_swizzle.json; \
	fi

test:
	cargo build --release
	cargo test -q

bench:
	cargo run --release --manifest-path rust/Cargo.toml --bin flux -- bench --json

# The exact trajectory CI's bench-smoke job runs: BENCH_0..4 byte-stable
# reports, BENCH_5 wall-clock events/sec, and the perf gate against
# artifacts/perf_baseline.json.
bench-ci:
	bash scripts/bench_trajectory.sh

fmt:
	cargo fmt --all

# clippy (incl. the clippy.toml mirror of the mechanical flux-lint
# rules) plus the full flux-lint pass: determinism rules D001-D005 over
# rust/src, pragma audit, panic-budget ratchet. See README "Determinism
# discipline".
lint:
	cargo clippy --all-targets -- -D warnings
	cargo run --release -p flux-lint

clean:
	cargo clean
	rm -f BENCH_*.json
