#!/usr/bin/env python3
"""Bit-exact Python mirror of rust/flux-lint's scanner.

Two jobs:

  1. Regenerate the D005 panic-budget ratchet after panic sites are
     removed (never to raise it):

         python3 scripts/lint_budget.py rust/src artifacts/lint_budget.json

  2. Cross-check the Rust scanner: rules D001-D004, pragma handling and
     the per-module panic counts below are the executable spec that
     rust/flux-lint/src/{lexer,lib}.rs ports line for line. A change to
     either side must land in both, and `flux lint` / this script must
     keep printing identical findings for the same tree.

See README "Determinism discipline" for the rule table and the pragma
grammar.
"""
import json
import os
import sys

PRAGMA_RULES = {"D001", "D002", "D003", "D004"}

# file-scope allowlists, keyed by rule, values are paths relative to
# rust/src with forward slashes.
FILE_ALLOW = {
    "D003": {"util/bench.rs"},
}

D004_IDENTS = {
    "thread_rng", "ThreadRng", "OsRng", "StdRng", "from_entropy",
    "getrandom", "RandomState",
}


def strip(text):
    """Blank comments, strings and char literals.

    Returns (blanked, line_comments) where `blanked` has the same
    char-for-char layout as `text` (non-code chars replaced by spaces,
    newlines preserved) and `line_comments` is a list of
    (line_no, comment_text) for every `//` comment (text after the
    slashes, up to but excluding the newline).
    """
    chars = list(text)
    n = len(chars)
    out = [" "] * n
    comments = []
    i = 0
    line = 1
    while i < n:
        c = chars[i]
        if c == "\n":
            out[i] = "\n"
            line += 1
            i += 1
            continue
        if c == "/" and i + 1 < n and chars[i + 1] == "/":
            # line comment: record text, blank to end of line
            j = i + 2
            while j < n and chars[j] != "\n":
                j += 1
            comments.append((line, "".join(chars[i + 2:j])))
            i = j
            continue
        if c == "/" and i + 1 < n and chars[i + 1] == "*":
            depth = 1
            i += 2
            while i < n and depth > 0:
                if chars[i] == "\n":
                    out[i] = "\n"
                    line += 1
                    i += 1
                elif chars[i] == "/" and i + 1 < n and chars[i + 1] == "*":
                    depth += 1
                    i += 2
                elif chars[i] == "*" and i + 1 < n and chars[i + 1] == "/":
                    depth -= 1
                    i += 2
                else:
                    i += 1
            continue
        if c == '"':
            i, line = skip_string(chars, i + 1, line, out)
            continue
        # raw strings r"..." / r#"..."# and byte strings b"..", br#".."#,
        # but NOT raw identifiers (r#foo) or plain idents ending in r/b.
        if c in ("r", "b") and not is_ident_char(chars[i - 1] if i else " "):
            j = i + 1
            if c == "b" and j < n and chars[j] == "r":
                j += 1
            hashes = 0
            while j < n and chars[j] == "#":
                hashes += 1
                j += 1
            if j < n and chars[j] == '"':
                if c == "b" and hashes == 0 and chars[i + 1] != '"' \
                        and chars[i + 1] != "r":
                    pass  # unreachable: j advanced only past r/#
                i, line = skip_raw_string(chars, j + 1, hashes, line, out)
                continue
            if c == "b" and i + 1 < n and chars[i + 1] == "'":
                # byte char literal b'x'
                i, line = skip_char_literal(chars, i + 2, line, out)
                continue
            # not a literal: fall through as code
        if c == "'":
            nxt = chars[i + 1] if i + 1 < n else " "
            nxt2 = chars[i + 2] if i + 2 < n else " "
            if nxt == "\\":
                i, line = skip_char_literal(chars, i + 1, line, out)
                continue
            if is_ident_start(nxt) and nxt2 != "'":
                # lifetime: blank the quote, keep the name as code
                i += 1
                continue
            if nxt2 == "'":
                i += 3  # 'x'
                continue
            i += 1  # stray quote (shouldn't happen in valid Rust)
            continue
        out[i] = c
        i += 1
    return "".join(out), comments


def skip_string(chars, i, line, out):
    n = len(chars)
    while i < n:
        c = chars[i]
        if c == "\n":
            out[i] = "\n"
            line += 1
            i += 1
        elif c == "\\":
            # `\<newline>` is a line continuation: the newline is still
            # a source line boundary.
            if i + 1 < n and chars[i + 1] == "\n":
                out[i + 1] = "\n"
                line += 1
            i += 2
        elif c == '"':
            return i + 1, line
        else:
            i += 1
    return i, line


def skip_raw_string(chars, i, hashes, line, out):
    n = len(chars)
    closing = '"' + "#" * hashes
    while i < n:
        if chars[i] == "\n":
            out[i] = "\n"
            line += 1
            i += 1
        elif chars[i] == '"' and "".join(chars[i:i + 1 + hashes]) == closing:
            return i + 1 + hashes, line
        else:
            i += 1
    return i, line


def skip_char_literal(chars, i, line, out):
    # i points at the backslash (or first interior char); scan to the
    # closing quote. Escapes like '\'' put the quote right after the
    # escaped char, '\u{..}' ends at the next quote either way.
    n = len(chars)
    if i < n and chars[i] == "\\":
        i += 2  # skip backslash + escaped char
    while i < n and chars[i] != "'":
        i += 1
    return i + 1, line


def is_ident_start(c):
    return c.isascii() and (c.isalpha() or c == "_")


def is_ident_char(c):
    return c.isascii() and (c.isalnum() or c == "_")


def tokenize(blanked):
    """[(line, kind, text)] with kind in {id, num, punct}."""
    toks = []
    line = 1
    i = 0
    n = len(blanked)
    while i < n:
        c = blanked[i]
        if c == "\n":
            line += 1
            i += 1
        elif c.isspace():
            i += 1
        elif is_ident_start(c):
            j = i
            while j < n and is_ident_char(blanked[j]):
                j += 1
            toks.append((line, "id", blanked[i:j]))
            i = j
        elif c.isascii() and c.isdigit():
            j = i
            while j < n and is_ident_char(blanked[j]):
                j += 1
            toks.append((line, "num", blanked[i:j]))
            i = j
        else:
            toks.append((line, "punct", c))
            i += 1
    return toks


def test_regions(toks):
    """Token-index spans [start, end) covered by #[cfg(test)] items."""
    spans = []
    n = len(toks)
    i = 0
    while i < n:
        if (
            toks[i][1:] == ("punct", "#")
            and i + 6 < n
            and toks[i + 1][1:] == ("punct", "[")
            and toks[i + 2][1:] == ("id", "cfg")
            and toks[i + 3][1:] == ("punct", "(")
            and toks[i + 4][1:] == ("id", "test")
            and toks[i + 5][1:] == ("punct", ")")
            and toks[i + 6][1:] == ("punct", "]")
        ):
            j = i + 7
            # the guarded item ends at the matching brace of its first
            # block, or at a semicolon if brace-less (e.g. a `use`).
            while j < n and toks[j][1:] not in (
                ("punct", "{"),
                ("punct", ";"),
            ):
                j += 1
            if j < n and toks[j][1:] == ("punct", "{"):
                depth = 1
                j += 1
                while j < n and depth > 0:
                    if toks[j][1:] == ("punct", "{"):
                        depth += 1
                    elif toks[j][1:] == ("punct", "}"):
                        depth -= 1
                    j += 1
            else:
                j = min(j + 1, n)
            spans.append((i, j))
            i = j
        else:
            i += 1
    return spans


def in_spans(spans, idx):
    return any(s <= idx < e for s, e in spans)


def parse_pragmas(comments, blanked_lines):
    """-> (pragmas, malformed) where pragmas are dicts with
    {line, target, rules, reason} and malformed is [(line, message)]."""
    pragmas = []
    malformed = []
    for line, text in comments:
        # Only `// flux-lint: ...` is a pragma attempt; prose mentions
        # ("flux-lint rule D003 bans ...") are ordinary comments.
        t = text.strip()
        if not t.startswith("flux-lint:"):
            continue
        ok = False
        rules = []
        reason = ""
        rest = t[len("flux-lint:"):].strip()
        if rest.startswith("allow(") and ")" in rest:
            inner, _, tail = rest[len("allow("):].partition(")")
            rules = [r.strip() for r in inner.split(",")]
            tail = tail.strip()
            if (
                rules
                and all(r in PRAGMA_RULES for r in rules)
                and tail.startswith("--")
                and tail[2:].strip()
            ):
                ok = True
                reason = tail[2:].strip()
        if not ok:
            malformed.append((
                line,
                "malformed flux-lint pragma: expected `// flux-lint: "
                "allow(D001[,D002...]) -- reason` (rules D001-D004)",
            ))
            continue
        code = blanked_lines[line - 1] if line - 1 < len(blanked_lines) else ""
        if code.strip() == "":
            # standalone comment line: applies to the next code line
            target = None
            for ln in range(line, len(blanked_lines)):
                if blanked_lines[ln].strip() != "":
                    target = ln + 1
                    break
        else:
            target = line
        pragmas.append({
            "line": line,
            "target": target,
            "rules": rules,
            "reason": reason,
        })
    return pragmas, malformed


def scan_file(rel, text):
    """-> (findings, allowed, counts)

    findings: [(rule, line, message)]
    allowed:  [(rule, line, reason)]
    counts:   {"unwrap": n, "expect": n, "panic": n}  (non-test code)
    """
    blanked, comments = strip(text)
    blanked_lines = blanked.split("\n")
    toks = tokenize(blanked)
    spans = test_regions(toks)
    pragmas, malformed = parse_pragmas(comments, blanked_lines)

    raw = []  # (rule, line, message) before pragma suppression
    counts = {"unwrap": 0, "expect": 0, "panic": 0}
    for idx, (line, kind, tok) in enumerate(toks):
        if kind != "id":
            continue
        prev = toks[idx - 1][1:] if idx > 0 else ("punct", " ")
        nxt = toks[idx + 1][1:] if idx + 1 < len(toks) else ("punct", " ")
        if tok in ("HashMap", "HashSet"):
            raw.append((
                "D001", line,
                f"{tok} iterates in hash order; use BTreeMap/BTreeSet "
                "or a Vec so report bytes stay stable",
            ))
        elif tok == "partial_cmp" and prev != ("id", "fn"):
            raw.append((
                "D002", line,
                "partial_cmp is not total on floats (NaN); use "
                "f64::total_cmp",
            ))
        elif tok in ("Instant", "SystemTime") and rel not in FILE_ALLOW["D003"]:
            raw.append((
                "D003", line,
                f"std::time::{tok} is wall clock; deterministic paths "
                "must route timing through util::bench (Stopwatch)",
            ))
        elif tok in D004_IDENTS:
            raw.append((
                "D004", line,
                f"{tok} draws OS entropy; construct RNGs via the "
                "seeded util::prng::Rng entry points",
            ))
        elif (
            tok in ("unwrap", "expect")
            and prev == ("punct", ".")
            and nxt == ("punct", "(")
            and not in_spans(spans, idx)
        ):
            counts[tok] += 1
        elif (
            tok == "panic"
            and nxt == ("punct", "!")
            and not in_spans(spans, idx)
        ):
            counts["panic"] += 1

    findings = [("D000", ln, msg) for ln, msg in malformed]
    allowed = []
    used = set()
    for rule, line, msg in raw:
        hit = None
        for pi, p in enumerate(pragmas):
            if p["target"] == line and rule in p["rules"]:
                hit = pi
                break
        if hit is None:
            findings.append((rule, line, msg))
        else:
            used.add(hit)
            allowed.append((rule, line, pragmas[hit]["reason"]))
    for pi, p in enumerate(pragmas):
        if pi not in used:
            findings.append((
                "D000", p["line"],
                "unused flux-lint allow pragma (suppresses nothing on "
                "its target line)",
            ))
    return findings, allowed, counts


def scan_tree(src_root):
    files = []
    for dirpath, dirnames, filenames in os.walk(src_root):
        dirnames.sort()
        for f in sorted(filenames):
            if f.endswith(".rs"):
                files.append(os.path.join(dirpath, f))
    files.sort(key=lambda p: os.path.relpath(p, src_root).replace(os.sep, "/"))
    all_findings = []
    all_allowed = []
    mod_counts = {}
    for path in files:
        rel = os.path.relpath(path, src_root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        findings, allowed, counts = scan_file(rel, text)
        for rule, line, msg in findings:
            all_findings.append((rel, line, rule, msg))
        for rule, line, reason in allowed:
            all_allowed.append((rel, line, rule, reason))
        mod_counts[rel] = counts
    all_findings.sort()
    all_allowed.sort()
    return all_findings, all_allowed, mod_counts


def main():
    src = sys.argv[1] if len(sys.argv) > 1 else "rust/src"
    findings, allowed, counts = scan_tree(src)
    for rel, line, rule, msg in findings:
        print(f"{rule} rust/src/{rel}:{line}: {msg}")
    for rel, line, rule, reason in allowed:
        print(f"allowed {rule} rust/src/{rel}:{line} -- {reason}")
    total = {"unwrap": 0, "expect": 0, "panic": 0}
    for rel in sorted(counts):
        c = counts[rel]
        if any(c.values()):
            print(f"budget {rel}: {c}")
            for k in total:
                total[k] += c[k]
    print(f"TOTAL sites: {total} findings: {len(findings)}")
    if len(sys.argv) > 2:
        budget = {
            "schema": "flux-lint-budget-v1",
            "note": (
                "Panic-budget ratchet (flux-lint D005): unwrap()/expect()"
                "/panic! sites per rust/src module, non-test code only. "
                "Counts may only go down; remove a site rather than "
                "raising its budget. Regenerate after removals: "
                "flux lint prints the slack to reclaim."
            ),
            "modules": {
                rel: {k: v for k, v in c.items() if v}
                for rel, c in sorted(counts.items())
                if any(c.values())
            },
        }
        with open(sys.argv[2], "w", encoding="utf-8") as fh:
            json.dump(budget, fh, indent=2)
            fh.write("\n")
        print(f"wrote {sys.argv[2]}")


if __name__ == "__main__":
    main()
