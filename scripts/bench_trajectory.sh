#!/usr/bin/env bash
# The bench trajectory: every deterministic report the repo ships, each
# written twice and byte-compared (`cmp`), plus the wall-clock engine
# throughput point and its perf gate. CI's bench-smoke job runs exactly
# this script; run it locally with `make bench-ci`.
#
# Outputs (uploaded as the CI artifact):
#   BENCH_0.json  op-level bench suite        (flux-bench-v1, byte-stable)
#   BENCH_1.json  serving-at-scale scenario   (flux-scale-v2, byte-stable)
#   BENCH_2.json  1F1B training sweep         (flux-train-v1, byte-stable)
#   BENCH_3.json  workload preset sweep       (flux-sweep-v1, byte-stable)
#   BENCH_4.json  sweep, 1 thread vs default  (parallel determinism)
#   BENCH_5.json  bench --wall: events/sec    (machine-local, NOT compared)
#   BENCH_6.json  replica-churn scenario      (flux-churn-v1, byte-stable)
#   BENCH_7.json  churn scenario + telemetry  (flux-metrics-v1, byte-stable)
#   BENCH_8.json  fleet dp64 + sketch pctls   (flux-scale-v2, byte-stable)
set -euo pipefail

cd "$(dirname "$0")/.."

flux() {
  cargo run --release --manifest-path rust/Cargo.toml --bin flux -- "$@"
}

# stable <out.json> <flux args...>: write the report, rerun it, and
# require the two runs to be byte-identical.
stable() {
  local out=$1
  shift
  flux "$@" --out "$out"
  head -c 2000 "$out"
  echo
  flux "$@" --out "$out.repro"
  cmp "$out" "$out.repro"
  rm -f "$out.repro"
}

echo "== BENCH_0: op-level bench suite (flux-bench-v1) =="
stable BENCH_0.json bench --json --quick

echo "== BENCH_1: serving-at-scale scenario (flux-scale-v2) =="
stable BENCH_1.json simulate --scale --json --quick

echo "== BENCH_2: 1F1B training sweep (flux-train-v1) =="
stable BENCH_2.json simulate --train --json --quick

echo "== BENCH_3: workload preset sweep (flux-sweep-v1) =="
stable BENCH_3.json sweep-workloads --json --quick

echo "== BENCH_4: parallel determinism (1 worker vs one-per-core) =="
flux sweep-workloads --json --quick --threads 1 --out BENCH_4.json
flux sweep-workloads --json --quick --out BENCH_4_par.json
cmp BENCH_4.json BENCH_4_par.json
rm -f BENCH_4_par.json

echo "== BENCH_6: replica-churn degradation curves (flux-churn-v1) =="
stable BENCH_6.json scenario artifacts/scenario_churn_h800.json --json

echo "== BENCH_7: churn telemetry (flux-metrics-v1) =="
# The metrics file is a side output next to the report, so the rerun
# compares both documents: the churn report AND the telemetry must be
# byte-identical across runs and thread counts.
flux scenario artifacts/scenario_churn_h800.json --json --threads 1 \
  --out BENCH_7.json --metrics BENCH_7_metrics.json
head -c 2000 BENCH_7_metrics.json
echo
flux scenario artifacts/scenario_churn_h800.json --json \
  --out BENCH_7.json.repro --metrics BENCH_7_metrics.json.repro
cmp BENCH_7.json BENCH_7.json.repro
cmp BENCH_7_metrics.json BENCH_7_metrics.json.repro
rm -f BENCH_7.json.repro BENCH_7_metrics.json.repro

echo "== BENCH_8: fleet dp64 pool + sketch percentiles (flux-scale-v2) =="
# The parametric fleet topologies and the opt-in sketch-percentile mode
# ride the same byte-stability contract as the named registry: the
# scenario file pins a dp64 pool with percentiles: "sketch", and the
# rerun must reproduce every sketch twin bit for bit.
stable BENCH_8.json scenario artifacts/scenario_fleet_sketch.json --json

echo "== BENCH_5: DES engine events/sec (wall clock; not byte-compared) =="
flux bench --json --quick --wall --out BENCH_5.json

echo "== perf gate: events/sec vs checked-in baseline =="
python3 scripts/perf_gate.py BENCH_5.json artifacts/perf_baseline.json
