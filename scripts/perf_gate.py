#!/usr/bin/env python3
"""Events/sec perf gate: fail CI when the measured DES-engine throughput
in a `flux bench --json --wall` report drops below the checked-in
baseline times its tolerance.

Usage: perf_gate.py <BENCH_5.json> <artifacts/perf_baseline.json>

The tolerance is deliberately generous (default 0.5x): shared CI runners
are noisy, and the gate exists to catch order-of-magnitude regressions
(an accidental O(log n) -> O(n) slip in the queue, a debug build), not
5% drift. Ratchet `events_per_sec` in the baseline upward as real CI
numbers accumulate — see README "Performance".
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    bench_path, baseline_path = sys.argv[1], sys.argv[2]
    with open(bench_path) as f:
        bench = json.load(f)
    with open(baseline_path) as f:
        base = json.load(f)

    schema = base.get("schema")
    if schema != "flux-perf-baseline-v1":
        print(f"{baseline_path}: unexpected schema {schema!r}", file=sys.stderr)
        return 2
    try:
        measured = bench["wall"]["events_per_sec"]["events_per_sec"]
    except KeyError:
        print(
            f"{bench_path}: no wall.events_per_sec.events_per_sec -- "
            "was the report written with --wall?",
            file=sys.stderr,
        )
        return 2

    baseline = float(base["events_per_sec"])
    tolerance = float(base["tolerance"])
    floor = baseline * tolerance
    print(
        f"measured {measured:.3e} events/s; baseline {baseline:.3e} "
        f"x tolerance {tolerance} -> floor {floor:.3e}"
    )
    if measured < floor:
        print(
            f"FAIL: events/sec regressed below the baseline floor "
            f"({measured:.3e} < {floor:.3e}). If this machine is simply "
            f"slower than the baseline assumes, lower "
            f"{baseline_path}; otherwise find the regression.",
            file=sys.stderr,
        )
        return 1
    print("perf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
