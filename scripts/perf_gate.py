#!/usr/bin/env python3
"""Events/sec perf gate: fail CI when the measured DES-engine throughput
in a `flux bench --json --wall` report drops below the checked-in
baseline times its tolerance.

Usage: perf_gate.py <BENCH_5.json> <artifacts/perf_baseline.json>

Two checks run:

1. The hold-workload throughput (`wall.events_per_sec.events_per_sec`)
   must clear `events_per_sec * tolerance` from the baseline file.
2. The dp64 fleet hold cell (`wall.fleet.cells[0].events_per_sec`) must
   stay within `fleet_factor` of the bare hold-model throughput: the
   fleet cell runs the same engine with a 4x larger resident
   population, so falling more than ~2x behind means the hot path
   stopped scaling (a bucket-width pathology, an accidental re-sort),
   not runner noise.

The tolerance is deliberately below 1.0 (0.7x after the first ratchet):
shared CI runners are noisy, and the gate exists to catch
order-of-magnitude regressions (an accidental O(log n) -> O(n) slip in
the queue, a debug build), not 5% drift.

Ratchet recipe: take the minimum `wall.events_per_sec` over the last
~20 green CI runs, set `events_per_sec` in the baseline to half of it,
and keep `tolerance` at 0.7. Never ratchet from a single fast run.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        return 2
    bench_path, baseline_path = sys.argv[1], sys.argv[2]
    with open(bench_path) as f:
        bench = json.load(f)
    with open(baseline_path) as f:
        base = json.load(f)

    schema = base.get("schema")
    if schema != "flux-perf-baseline-v1":
        print(f"{baseline_path}: unexpected schema {schema!r}", file=sys.stderr)
        return 2
    try:
        measured = bench["wall"]["events_per_sec"]["events_per_sec"]
    except KeyError:
        print(
            f"{bench_path}: no wall.events_per_sec.events_per_sec -- "
            "was the report written with --wall?",
            file=sys.stderr,
        )
        return 2

    baseline = float(base["events_per_sec"])
    tolerance = float(base["tolerance"])
    floor = baseline * tolerance
    print(
        f"measured {measured:.3e} events/s; baseline {baseline:.3e} "
        f"x tolerance {tolerance} -> floor {floor:.3e}"
    )
    if measured < floor:
        print(
            f"FAIL: events/sec regressed below the baseline floor "
            f"({measured:.3e} < {floor:.3e}). If this machine is simply "
            f"slower than the baseline assumes, lower "
            f"{baseline_path}; otherwise find the regression.",
            file=sys.stderr,
        )
        return 1

    # Fleet cell: relative check against the just-measured hold
    # throughput, so it is immune to absolute runner speed.
    fleet_factor = base.get("fleet_factor")
    if fleet_factor is not None:
        try:
            cells = bench["wall"]["fleet"]["cells"]
            fleet = cells[0]["events_per_sec"]
            dp = cells[0]["dp"]
        except (KeyError, IndexError):
            print(
                f"{bench_path}: no wall.fleet.cells[0].events_per_sec "
                "-- bench report predates the fleet section?",
                file=sys.stderr,
            )
            return 2
        factor = float(fleet_factor)
        fleet_floor = measured / factor
        print(
            f"fleet dp{dp} {fleet:.3e} events/s; hold {measured:.3e} "
            f"/ factor {factor} -> floor {fleet_floor:.3e}"
        )
        if fleet < fleet_floor:
            print(
                f"FAIL: the dp{dp} fleet cell fell more than {factor}x "
                f"behind the bare hold model ({fleet:.3e} < "
                f"{fleet_floor:.3e}): the engine hot path stopped "
                "scaling with the resident population.",
                file=sys.stderr,
            )
            return 1

    print("perf gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
