//! In-tree stub of the `xla` crate (the PJRT bindings the FLUX runtime
//! uses to execute AOT-lowered HLO artifacts on the CPU client).
//!
//! The real bindings (`xla` / `xla_extension`) link libxla, which is not
//! vendored in this tree. This stub provides the exact API surface
//! `flux::runtime` and `flux::serving::engine` consume so the workspace
//! builds, tests and ships hermetically:
//!
//! * [`Literal`] is fully functional host-side (shape + typed buffer,
//!   reshape, extraction) — `flux::runtime::literal_f32`/`literal_i32`
//!   and their tests work against it for real.
//! * [`PjRtClient::cpu`] succeeds, but [`PjRtClient::compile`] returns
//!   [`XlaError::BackendUnavailable`]: anything that would actually run
//!   an HLO program reports a clean error instead of wrong numbers.
//!   Callers probe [`backend_available`] (re-exported as
//!   `Runtime::pjrt_available`) and skip PJRT-dependent paths.
//!
//! Swapping in the real crate is a one-line change in rust/Cargo.toml
//! (`xla = { path = "../xla-stub" }` -> the vendored bindings); no flux
//! source changes are required.

use std::fmt;
use std::path::Path;

/// Does this build have a live PJRT backend? The stub never does.
pub const fn backend_available() -> bool {
    false
}

/// Error type matching the real bindings' surface: call sites only ever
/// format it with `{:?}` / `{}` inside `anyhow!`.
#[derive(Clone, Debug)]
pub enum XlaError {
    BackendUnavailable(String),
    ShapeMismatch(String),
    TypeMismatch(String),
    Io(String),
}

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            XlaError::BackendUnavailable(m) => {
                write!(f, "PJRT backend unavailable: {m}")
            }
            XlaError::ShapeMismatch(m) => write!(f, "shape mismatch: {m}"),
            XlaError::TypeMismatch(m) => write!(f, "type mismatch: {m}"),
            XlaError::Io(m) => write!(f, "io error: {m}"),
        }
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError::BackendUnavailable(format!(
        "{what} requires the real xla/PJRT bindings; this build uses the \
         in-tree stub (see xla-stub/src/lib.rs). Simulator, goldens and \
         bench paths are unaffected."
    )))
}

// ---------------------------------------------------------------------------
// Literal: a real host-side typed tensor.
// ---------------------------------------------------------------------------

/// Element types the flux runtime moves across the boundary. Public
/// only because [`NativeType`]'s conversion hooks mention it; treat it
/// as an implementation detail.
#[doc(hidden)]
#[derive(Clone, Debug, PartialEq)]
pub enum Buf {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host literal: shape + buffer. Mirrors `xla::Literal`'s construction
/// and extraction API (`vec1`, `reshape`, `to_vec`, `to_tuple`).
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    buf: Buf,
}

/// Sealed-ish conversion trait mirroring the real crate's `NativeType`.
pub trait NativeType: Sized + Copy {
    fn buf_from(v: &[Self]) -> Buf;
    fn buf_to(buf: &Buf) -> Option<Vec<Self>>;
    const NAME: &'static str;
}

impl NativeType for f32 {
    fn buf_from(v: &[Self]) -> Buf {
        Buf::F32(v.to_vec())
    }
    fn buf_to(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const NAME: &'static str = "f32";
}

impl NativeType for i32 {
    fn buf_from(v: &[Self]) -> Buf {
        Buf::I32(v.to_vec())
    }
    fn buf_to(buf: &Buf) -> Option<Vec<Self>> {
        match buf {
            Buf::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
    const NAME: &'static str = "i32";
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], buf: T::buf_from(data) }
    }

    /// Tuple literal (what a `return_tuple=True` computation yields).
    pub fn tuple(elems: Vec<Literal>) -> Literal {
        Literal { dims: vec![elems.len() as i64], buf: Buf::Tuple(elems) }
    }

    fn element_count(&self) -> usize {
        match &self.buf {
            Buf::F32(v) => v.len(),
            Buf::I32(v) => v.len(),
            Buf::Tuple(v) => v.len(),
        }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        if matches!(self.buf, Buf::Tuple(_)) {
            return Err(XlaError::TypeMismatch(
                "cannot reshape a tuple literal".to_string(),
            ));
        }
        if want != have {
            return Err(XlaError::ShapeMismatch(format!(
                "reshape to {dims:?} wants {want} elements, literal has \
                 {have}"
            )));
        }
        Ok(Literal { dims: dims.to_vec(), buf: self.buf.clone() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Extract the host buffer.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::buf_to(&self.buf).ok_or_else(|| {
            XlaError::TypeMismatch(format!(
                "literal does not hold {} elements",
                T::NAME
            ))
        })
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.buf {
            Buf::Tuple(v) => Ok(v),
            _ => Err(XlaError::TypeMismatch(
                "literal is not a tuple".to_string(),
            )),
        }
    }
}

// ---------------------------------------------------------------------------
// HLO text + computation handles.
// ---------------------------------------------------------------------------

/// Parsed-HLO handle. The stub stores the artifact text verbatim (so
/// missing-file errors surface exactly as with the real parser) but does
/// not build a real module.
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            XlaError::Io(format!("{}: {e}", path.display()))
        })?;
        if text.trim().is_empty() {
            return Err(XlaError::Io(format!(
                "{}: empty HLO text",
                path.display()
            )));
        }
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

#[derive(Clone, Debug)]
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _text: proto.text.clone() }
    }
}

// ---------------------------------------------------------------------------
// PJRT client / executable handles.
// ---------------------------------------------------------------------------

/// CPU PJRT client handle. Construction succeeds (manifest loading and
/// artifact bookkeeping work hermetically); `compile` is where the stub
/// reports the missing backend.
#[derive(Clone, Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {})
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable> {
        unavailable("compiling an HLO computation")
    }
}

/// Compiled-executable handle (never constructed by the stub client, but
/// the type must exist for the runtime's executable cache).
#[derive(Clone, Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executing a compiled artifact")
    }
}

/// Device-buffer handle returned by `execute`.
#[derive(Clone, Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("fetching a device buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(
            r.to_vec::<f32>().unwrap(),
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]
        );
        assert!(r.to_vec::<i32>().is_err(), "typed extraction is checked");
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[1i32, 2, 3]);
        assert!(l.reshape(&[2, 2]).is_err());
        assert!(l.reshape(&[3, 1]).is_ok());
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::tuple(vec![
            Literal::vec1(&[1.0f32]),
            Literal::vec1(&[2i32]),
        ]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[0i32]).to_tuple().is_err());
    }

    #[test]
    fn backend_reports_unavailable() {
        assert!(!backend_available());
        let client = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto { text: "HloModule m".to_string() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("unavailable"));
    }

    #[test]
    fn missing_hlo_file_is_an_io_error() {
        let err =
            HloModuleProto::from_text_file("/nonexistent/x.hlo.txt")
                .unwrap_err();
        assert!(matches!(err, XlaError::Io(_)));
    }
}
